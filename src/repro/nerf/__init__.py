"""Instant-NGP in JAX: multi-resolution hash encoding + tiny MLPs + volume
rendering, with first-class mixed-precision quantization hooks (the paper's
quantizable modules: every hash-table level and every MLP layer's weights and
input activations).
"""
from repro.nerf.hash_encoding import HashEncodingConfig, init_hash_tables, hash_encode
from repro.nerf.ngp import (
    NGPConfig,
    NGPQuantSpec,
    init_ngp,
    ngp_apply,
    ngp_linear_names,
    make_quant_units,
    no_quant_spec,
    spec_from_policy,
)
from repro.nerf.render import render_rays, RenderConfig
from repro.nerf.scenes import SceneConfig, make_scene, render_ground_truth
from repro.nerf.dataset import NGPDataset, make_dataset
from repro.nerf.train import train_ngp, psnr, TrainConfig, evaluate_psnr, finetune_ngp
from repro.nerf.occupancy import (
    OccupancyGrid,
    bake_occupancy,
    cull_budget,
    occupancy_lookup,
)
from repro.nerf.fast_render import (
    FastRenderEngine,
    FusedPack,
    build_fused_pack,
    fast_render_rays,
    fused_ngp_apply,
    fused_pack_stored_bytes,
)

__all__ = [
    "OccupancyGrid",
    "bake_occupancy",
    "cull_budget",
    "occupancy_lookup",
    "FastRenderEngine",
    "FusedPack",
    "build_fused_pack",
    "fast_render_rays",
    "fused_ngp_apply",
    "fused_pack_stored_bytes",
    "HashEncodingConfig",
    "init_hash_tables",
    "hash_encode",
    "NGPConfig",
    "NGPQuantSpec",
    "init_ngp",
    "ngp_apply",
    "ngp_linear_names",
    "make_quant_units",
    "no_quant_spec",
    "spec_from_policy",
    "render_rays",
    "RenderConfig",
    "SceneConfig",
    "make_scene",
    "render_ground_truth",
    "NGPDataset",
    "make_dataset",
    "train_ngp",
    "finetune_ngp",
    "psnr",
    "TrainConfig",
    "evaluate_psnr",
]
