"""NGP training / finetuning / PSNR evaluation.

`train_ngp` fits a fresh model (full precision). `finetune_ngp` is the
retraining step of the HERO episode (Sec. III-E): short QAT through the
fake-quantized forward with the episode's bit assignment. Both are built on
a single jit'd step whose quantization spec is *traced*, so one compile
serves every policy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nerf.dataset import NGPDataset
from repro.nerf.ngp import NGPConfig, NGPQuantSpec, init_ngp, no_quant_spec
from repro.nerf.render import RenderConfig, render_rays
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 400
    batch_rays: int = 512
    lr: float = 5e-3
    finetune_lr: float = 1e-3
    weight_decay: float = 1e-6
    grad_clip: float = 10.0
    seed: int = 0
    eval_ray_chunk: int = 4096


def psnr(mse: float) -> float:
    return float(-10.0 * np.log10(max(mse, 1e-12)))


def _loss_fn(params, rays_o, rays_d, target, cfg, rcfg, spec, key):
    color, _ = render_rays(params, rays_o, rays_d, cfg, rcfg, spec, key)
    return jnp.mean((color - target) ** 2)


@functools.partial(jax.jit, static_argnames=("cfg", "rcfg", "opt_cfg"))
def _train_step(params, opt_state, rays_o, rays_d, target, key, spec, cfg, rcfg, opt_cfg):
    loss, grads = jax.value_and_grad(_loss_fn)(
        params, rays_o, rays_d, target, cfg, rcfg, spec, key
    )
    grads, _ = clip_by_global_norm(grads, 10.0)
    params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
    return params, opt_state, loss


def _run_steps(
    params,
    dataset: NGPDataset,
    cfg: NGPConfig,
    rcfg: RenderConfig,
    tcfg: TrainConfig,
    spec: NGPQuantSpec,
    steps: int,
    lr: float,
    seed: int,
):
    opt_cfg = AdamWConfig(lr=lr, weight_decay=tcfg.weight_decay)
    opt_state = adamw_init(params)
    key = jax.random.PRNGKey(seed)
    batches = dataset.ray_batches(tcfg.batch_rays, seed=seed)
    loss = None
    for _ in range(steps):
        ro, rd, c = next(batches)
        key, sub = jax.random.split(key)
        params, opt_state, loss = _train_step(
            params,
            opt_state,
            jnp.asarray(ro),
            jnp.asarray(rd),
            jnp.asarray(c),
            sub,
            spec,
            cfg,
            rcfg,
            opt_cfg,
        )
    return params, float(loss) if loss is not None else float("nan")


def train_ngp(
    dataset: NGPDataset,
    cfg: NGPConfig,
    rcfg: RenderConfig,
    tcfg: TrainConfig,
) -> Tuple[Dict, float]:
    """Train a fresh full-precision NGP. Returns (params, final_loss)."""
    params = init_ngp(jax.random.PRNGKey(tcfg.seed), cfg)
    spec = no_quant_spec(cfg)
    return _run_steps(
        params, dataset, cfg, rcfg, tcfg, spec, tcfg.steps, tcfg.lr, tcfg.seed
    )


def finetune_ngp(
    params: Dict,
    dataset: NGPDataset,
    cfg: NGPConfig,
    rcfg: RenderConfig,
    tcfg: TrainConfig,
    spec: NGPQuantSpec,
    steps: int,
) -> Tuple[Dict, float]:
    """QAT finetune under a quantization spec (the episode retraining)."""
    return _run_steps(
        params,
        dataset,
        cfg,
        rcfg,
        tcfg,
        spec,
        steps,
        tcfg.finetune_lr,
        tcfg.seed + 1,
    )


def evaluate_psnr(
    params: Dict,
    dataset: NGPDataset,
    cfg: NGPConfig,
    rcfg: RenderConfig,
    spec: Optional[NGPQuantSpec] = None,
    chunk: int = 4096,
    occ=None,
    mode: str = "reference",
    budget: Optional[int] = None,
) -> float:
    """Mean PSNR over held-out test views.

    Frames are rendered device-resident (`lax.map` over ray chunks with
    on-device squared-error accumulation) — one scalar crosses to the host
    per view regardless of mode. `mode="reference"` renders through the
    fake-quant oracle; `mode="fused"` through the integer kernel path,
    with empty-space culling when an `OccupancyGrid` is passed as `occ`
    (see `repro.nerf.fast_render`).
    """
    from repro.nerf.fast_render import FastRenderEngine

    engine = FastRenderEngine(
        params, cfg, rcfg, spec=spec, occ=occ, mode=mode, chunk=chunk,
        budget=budget,
    )
    return engine.evaluate_psnr(dataset)


def render_test_view(
    params: Dict,
    dataset: NGPDataset,
    cfg: NGPConfig,
    rcfg: RenderConfig,
    view: int = 0,
    spec: Optional[NGPQuantSpec] = None,
    chunk: int = 4096,
    occ=None,
    mode: str = "reference",
) -> np.ndarray:
    """Render one held-out view to an (hw, hw, 3) image (for Fig. 5-style
    qualitative comparisons)."""
    from repro.nerf.fast_render import FastRenderEngine

    engine = FastRenderEngine(
        params, cfg, rcfg, spec=spec, occ=occ, mode=mode, chunk=chunk
    )
    colors = engine.render_frame(
        dataset.test_rays_o[view], dataset.test_rays_d[view]
    )
    hw = dataset.cfg.image_hw
    return np.asarray(colors).reshape(hw, hw, 3)
