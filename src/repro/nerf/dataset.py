"""Dataset: posed ground-truth images + ray batch iterator for NGP training."""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nerf.scenes import (
    SceneConfig,
    camera_poses,
    camera_rays,
    make_scene,
    render_ground_truth,
)


@dataclasses.dataclass
class NGPDataset:
    scene_name: str
    cfg: SceneConfig
    # Flattened over all train views:
    train_rays_o: np.ndarray  # (N, 3)
    train_rays_d: np.ndarray  # (N, 3)
    train_rgb: np.ndarray  # (N, 3)
    # Per test view:
    test_rays_o: np.ndarray  # (V, hw*hw, 3)
    test_rays_d: np.ndarray  # (V, hw*hw, 3)
    test_rgb: np.ndarray  # (V, hw*hw, 3)

    def ray_batches(
        self, batch_size: int, seed: int = 0
    ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Infinite shuffled ray batches (deterministic given seed)."""
        rng = np.random.RandomState(seed)
        n = self.train_rays_o.shape[0]
        while True:
            idx = rng.randint(0, n, size=batch_size)
            yield self.train_rays_o[idx], self.train_rays_d[idx], self.train_rgb[idx]


def make_dataset(cfg: SceneConfig) -> NGPDataset:
    scene = make_scene(cfg.name)
    focal = cfg.focal_mult * cfg.image_hw
    train_poses, test_poses = camera_poses(cfg)

    render = jax.jit(
        lambda o, d: render_ground_truth(scene, o, d, cfg)
    )

    tr_o, tr_d, tr_c = [], [], []
    for pose in train_poses:
        o, d = camera_rays(jnp.asarray(pose), cfg.image_hw, focal)
        c = render(o, d)
        tr_o.append(np.asarray(o))
        tr_d.append(np.asarray(d))
        tr_c.append(np.asarray(c))

    te_o, te_d, te_c = [], [], []
    for pose in test_poses:
        o, d = camera_rays(jnp.asarray(pose), cfg.image_hw, focal)
        c = render(o, d)
        te_o.append(np.asarray(o))
        te_d.append(np.asarray(d))
        te_c.append(np.asarray(c))

    return NGPDataset(
        scene_name=cfg.name,
        cfg=cfg,
        train_rays_o=np.concatenate(tr_o),
        train_rays_d=np.concatenate(tr_d),
        train_rgb=np.concatenate(tr_c),
        test_rays_o=np.stack(te_o),
        test_rays_d=np.stack(te_d),
        test_rgb=np.stack(te_c),
    )
