"""Occupancy grid: empty-space culling for the fused render engine.

Baked ONCE from a (pre)trained field by thresholding density on a dense
grid (Instant NGP's occupancy-grid idea, simplified to a static bake: the
HERO reward loop renders thousands of frames from one frozen geometry, so
there is nothing to keep updating). Baking supersamples each cell and
dilates the result so that a cell is only marked empty when a neighborhood
around it is below the density threshold — culled samples then contribute
~zero weight and the fused renderer matches the dense reference to well
under the 0.1 dB acceptance band.

The grid is registered as a pytree whose resolution/occupancy statistics
are static metadata: jitted renderers can derive static sample budgets
from `occupied_fraction` without retracing per frame.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OccupancyGrid:
    """Boolean occupancy over the unit cube [0,1]^3, stored as f32 {0,1}."""

    occ: jnp.ndarray  # (G, G, G) f32, 1.0 = occupied
    resolution: int
    threshold: float
    occupied_fraction: float  # host-side stat, static under jit

    @property
    def n_occupied(self) -> int:
        return int(round(self.occupied_fraction * self.resolution**3))


jax.tree_util.register_dataclass(
    OccupancyGrid,
    data_fields=["occ"],
    meta_fields=["resolution", "threshold", "occupied_fraction"],
)


def _dilate_max3(occ: jnp.ndarray, iterations: int) -> jnp.ndarray:
    """3x3x3 max-pool dilation (SAME padding), `iterations` times."""
    for _ in range(iterations):
        occ = jax.lax.reduce_window(
            occ, -jnp.inf, jax.lax.max,
            window_dimensions=(3, 3, 3), window_strides=(1, 1, 1),
            padding="SAME",
        )
    return occ


def dilate_occupancy(grid: "OccupancyGrid", cells: int) -> "OccupancyGrid":
    """Grid with every occupied cell grown by `cells` in Chebyshev
    distance. Any point within `cells / resolution` (L-inf, world units:
    the box is unit-sized) of an occupied cell of the source grid lands
    in an occupied cell of the result — the conservative-coverage
    property the pose-cache warp tier relies on."""
    if cells <= 0:
        return grid
    occ = _dilate_max3(grid.occ, int(cells))
    return OccupancyGrid(
        occ=occ, resolution=grid.resolution, threshold=grid.threshold,
        occupied_fraction=float(jnp.mean(occ)),
    )


def ray_t_samples(rcfg) -> np.ndarray:
    """THE deterministic eval t-samples: (n_samples,) f32, host-computed.

    Single source of truth shared by every non-stratified path — the
    host-side plan/budget oracles here AND the device renderer
    (`fast_render` stages this exact array as a jit constant). Computing
    t once is what makes plan compaction and on-device compaction
    byte-identical end-to-end; `np.linspace` vs `jnp.linspace` differ by
    ~1 ulp and used to be the only divergence between the two paths.
    """
    return np.linspace(rcfg.near, rcfg.far, rcfg.n_samples, dtype=np.float32)


def bake_occupancy(
    params: Dict,
    cfg,  # NGPConfig
    resolution: int = 32,
    threshold: float = 1e-2,
    supersample: int = 2,
    dilate: int = 1,
    chunk: int = 65536,
    spec=None,
) -> OccupancyGrid:
    """Query sigma on a (resolution * supersample)^3 grid of the unit cube,
    max-pool down to resolution^3, threshold, dilate. One-time host loop."""
    from repro.nerf.ngp import ngp_apply

    fine = resolution * supersample
    axis = (np.arange(fine, dtype=np.float32) + 0.5) / fine
    gx, gy, gz = np.meshgrid(axis, axis, axis, indexing="ij")
    pts = np.stack([gx, gy, gz], axis=-1).reshape(-1, 3)
    dirs = np.broadcast_to(
        np.asarray([0.0, 0.0, 1.0], np.float32), pts.shape
    )  # sigma is view-independent

    query = jax.jit(
        lambda p, d: ngp_apply(params, p, d, cfg, spec)[0],
    )
    sig = np.empty(pts.shape[0], np.float32)
    for s in range(0, pts.shape[0], chunk):
        sig[s : s + chunk] = np.asarray(
            query(jnp.asarray(pts[s : s + chunk]), jnp.asarray(dirs[s : s + chunk]))
        )

    sig = jnp.asarray(sig.reshape(fine, fine, fine))
    if supersample > 1:
        sig = jax.lax.reduce_window(
            sig, -jnp.inf, jax.lax.max,
            window_dimensions=(supersample,) * 3,
            window_strides=(supersample,) * 3,
            padding="VALID",
        )
    occ = (sig > threshold).astype(jnp.float32)
    if dilate > 0:
        occ = _dilate_max3(occ, dilate)
    return OccupancyGrid(
        occ=occ,
        resolution=resolution,
        threshold=float(threshold),
        occupied_fraction=float(jnp.mean(occ)),
    )


# ---------------------------------------------------------------------------
# Bake registry: one grid per (weights, config) — shared across env instances
# ---------------------------------------------------------------------------
# The closed-loop search instantiates several envs per scene (one per
# hardware budget, plus batched wrappers); each bake is a dense host-side
# sigma sweep, so re-baking per instantiation multiplies the dominant
# setup cost for identical grids. The registry keys on a fingerprint of
# the frozen pretrained weights plus every bake parameter, so two envs on
# the same scene share ONE grid object while a finetuned/retrained model
# (different weights) still gets its own bake.
_BAKE_REGISTRY: Dict[tuple, OccupancyGrid] = {}
_BAKE_REGISTRY_CAP = 64


def params_fingerprint(params: Dict) -> str:
    """Content hash of a parameter pytree (order-independent leaf paths)."""
    import hashlib

    h = hashlib.sha256()
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in sorted(leaves, key=lambda kv: str(kv[0])):
        h.update(str(path).encode())
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()[:24]


def clear_occupancy_registry() -> None:
    _BAKE_REGISTRY.clear()


def occupancy_registry_size() -> int:
    return len(_BAKE_REGISTRY)


def bake_occupancy_cached(
    params: Dict,
    cfg,  # NGPConfig
    resolution: int = 32,
    threshold: float = 1e-2,
    supersample: int = 2,
    dilate: int = 1,
    chunk: int = 65536,
) -> OccupancyGrid:
    """`bake_occupancy` behind a content-addressed registry: identical
    (weights, config, bake knobs) return the SAME grid object."""
    key = (
        params_fingerprint(params), repr(cfg),
        resolution, float(threshold), supersample, dilate,
    )
    grid = _BAKE_REGISTRY.get(key)
    if grid is None:
        if len(_BAKE_REGISTRY) >= _BAKE_REGISTRY_CAP:
            _BAKE_REGISTRY.clear()  # bakes recompute exactly; cheap reset
        grid = bake_occupancy(
            params, cfg, resolution=resolution, threshold=threshold,
            supersample=supersample, dilate=dilate, chunk=chunk,
        )
        _BAKE_REGISTRY[key] = grid
    return grid


def occupancy_lookup(grid: OccupancyGrid, pts_unit: jnp.ndarray) -> jnp.ndarray:
    """(..., 3) points in [0,1] -> (...,) bool, True = occupied cell."""
    idx = jnp.clip(
        (pts_unit * grid.resolution).astype(jnp.int32), 0, grid.resolution - 1
    )
    return grid.occ[idx[..., 0], idx[..., 1], idx[..., 2]] > 0.5


def sample_active_mask(
    grid: OccupancyGrid,
    rays_o: np.ndarray,  # (..., 3)
    rays_d: np.ndarray,  # (..., 3)
    rcfg,  # RenderConfig (deterministic eval sampling)
    margin: float = 0.0,
):
    """Host-side oracle for which samples the renderer may cull.

    Returns (active (..., S) bool, pts (..., S, 3)): a sample is active
    iff it lies inside the scene box AND in an occupied grid cell. This is
    the single source of truth shared by `cull_budget` and the renderer's
    `CullPlan` builder — the two must count identically or budgets
    silently under-cover.

    `margin > 0` (world units) computes the CONSERVATIVE mask used by
    warped pose-cache plans: the box test expands by `margin` and the
    occupancy dilates by `ceil(margin * resolution)` cells, so the
    returned mask is a superset of the exact (`margin=0`) mask of ANY ray
    set whose per-sample points deviate from these by at most `margin`
    in L-inf.
    """
    ro = np.asarray(rays_o, np.float32)
    rd = np.asarray(rays_d, np.float32)
    t = ray_t_samples(rcfg)
    pts = ro[..., None, :] + rd[..., None, :] * t[:, None]
    lo, hi = -0.5 - margin, 0.5 + margin
    inside = np.all((pts > lo) & (pts < hi), axis=-1)
    g = grid.resolution
    occ = grid.occ
    if margin > 0.0:
        occ = _dilate_max3(occ, int(np.ceil(margin * g)))
    cell = np.clip(((pts + 0.5) * g).astype(np.int64), 0, g - 1)
    occ_np = np.asarray(occ) > 0.5
    return inside & occ_np[cell[..., 0], cell[..., 1], cell[..., 2]], pts


def cull_budget(
    grid: Optional[OccupancyGrid],
    rays_o: np.ndarray,  # (N, 3) — ALL rays the budget must cover
    rays_d: np.ndarray,
    rcfg,  # RenderConfig
    chunk: int,
    slack: float = 1.15,
    align: int = 128,
) -> int:
    """Static per-chunk sample budget for the compacting renderer.

    Counts the occupied samples of every `chunk`-ray slice of the given
    rays (deterministic eval sampling), takes the max. The active mask is
    params-independent, so the count is EXACT for these rays; `slack`
    only buys headroom when the returned budget is reused for ray
    populations beyond the ones counted here (an overflow silently drops
    the overflowing samples). One-time host cost.
    """
    n_samples = rcfg.n_samples
    if grid is None:
        return chunk * n_samples
    ro = np.asarray(rays_o, np.float32).reshape(-1, 3)
    rd = np.asarray(rays_d, np.float32).reshape(-1, 3)
    worst = 0
    for s in range(0, ro.shape[0], chunk):
        active, _ = sample_active_mask(
            grid, ro[s : s + chunk], rd[s : s + chunk], rcfg
        )
        worst = max(worst, int(np.sum(active)))
    budget = int(np.ceil(worst * slack / align) * align)
    return int(np.clip(budget, align, chunk * n_samples))
