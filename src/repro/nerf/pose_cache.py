"""Pose-grid plan cache: compiled cull plans for ad-hoc camera poses.

The serve engine renders requests from poses it has never seen; building
a `CullPlan` per request would dwarf the render. But real clients orbit,
dolly, and revisit: poses cluster. This module quantizes each request's
pose onto a configurable position/orientation grid and caches, per
(scene, pose cell, chunk), a compiled `WarpPlan` with THREE uses:

- **hit**: the slot's rays fingerprint-match the plan's reference rays —
  serve the baked plan (precomputed gathers + hash corners + SH bases,
  fixed-ray `CullPlan` speed).
- **warp**: the rays deviate from the reference but by less than the
  plan's coverage margin — reuse the CONSERVATIVE compaction indices for
  the new rays (field inputs are the actual points, the final mask
  re-intersects with the exact device march, so coverage — not the
  reference pose — decides correctness).
- **miss**: no plan or too much deviation — the on-device ray-march path
  renders, and the cell's use count decides whether to build a plan.

Conservativeness is the load-bearing property: a plan built from
`sample_active_mask(..., margin=m)` (box grown by `m`, occupancy dilated
by `ceil(m * resolution)` cells) covers every exact-active sample of ANY
rays whose per-sample points deviate from the reference by at most `m`
in L-inf (|floor(u) - floor(v)| <= ceil(|u - v|), and the box clip is a
projection, so clipping can only shrink the deviation). The deviation
bound per sample is `max|d_o|_inf + t_far * max|d_d|_inf` over the slot
(`warp_deviation`), with `t_far = max(|near|, |far|)` bounding every
sample depth. Reused plans therefore never cull a sample the exact plan
would keep — warped renders match the march tier's sample set exactly.

LRU eviction by pose cell; pinned (in-flight) cells are never evicted —
the engine pins a cell at submit and unpins when the request's slots
rendered or dropped. Plan bytes are charged to the engine's
`resident_bytes` so artifact-cache pressure sees them.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nerf.hash_encoding import level_corner_data
from repro.nerf.ngp import sh_encode
from repro.nerf.occupancy import OccupancyGrid, sample_active_mask


@dataclasses.dataclass(frozen=True)
class PoseGridConfig:
    """Quantization grid + cache policy (engine-level knobs)."""

    pos_cell: float = 0.05  # world units per position cell
    dir_cell: float = 0.05  # direction-component units per cell
    margin_cells: float = 1.0  # warp coverage margin, in OCC grid cells
    entries: int = 128  # LRU capacity (pose cells per engine)
    build_after: int = 2  # build plans on the Nth request visit of a cell

    def margin(self, occ: OccupancyGrid) -> float:
        """World-space coverage margin for this scene's grid."""
        return float(self.margin_cells) / float(occ.resolution)


def pose_cell_key(
    rays_o, rays_d, pos_cell: float, dir_cell: float
) -> Tuple[int, ...]:
    """Deterministic pose-grid cell of a ray bundle.

    Quantizes the mean ray origin (the camera position for pinhole
    bundles) by `pos_cell` and the first and last ray directions (which
    pin the orientation and field of view) by `dir_cell`, all by floor —
    equal bundles always land in equal cells, nearby poses usually do.
    """
    ro = np.asarray(rays_o, np.float32).reshape(-1, 3)
    rd = np.asarray(rays_d, np.float32).reshape(-1, 3)
    o = np.floor(ro.mean(axis=0) / pos_cell).astype(np.int64)
    d0 = np.floor(rd[0] / dir_cell).astype(np.int64)
    d1 = np.floor(rd[-1] / dir_cell).astype(np.int64)
    return tuple(o.tolist()) + tuple(d0.tolist()) + tuple(d1.tolist())


def ray_fingerprint(rays_o: np.ndarray, rays_d: np.ndarray) -> str:
    """Content hash of a (padded) slot ray bundle — the hit-tier test."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(rays_o, np.float32).tobytes())
    h.update(np.ascontiguousarray(rays_d, np.float32).tobytes())
    return h.hexdigest()


def warp_deviation(
    rays_o, rays_d, ref_o: np.ndarray, ref_d: np.ndarray, rcfg
) -> float:
    """Upper bound on the per-sample L-inf deviation of these rays'
    sample points from the reference rays' (shape mismatch -> inf)."""
    ro = np.asarray(rays_o, np.float32)
    rd = np.asarray(rays_d, np.float32)
    if ro.shape != ref_o.shape:
        return float("inf")
    t_far = max(abs(float(rcfg.near)), abs(float(rcfg.far)))
    d_o = float(np.max(np.abs(ro - ref_o), initial=0.0))
    d_d = float(np.max(np.abs(rd - ref_d), initial=0.0))
    return d_o + t_far * d_d


@functools.lru_cache(maxsize=8)
def _bake_fns(hash_cfg, n_levels: int, sh_degree: int):
    """Jitted corner/SH bake helpers, cached so repeated plan builds
    (one per pose cell) reuse one trace."""
    corner = jax.jit(
        lambda p: tuple(
            level_corner_data(p, l, hash_cfg) for l in range(n_levels)
        )
    )
    sh = jax.jit(lambda d: sh_encode(d, sh_degree))
    return corner, sh


@dataclasses.dataclass
class WarpPlan:
    """One pose cell's compiled compaction for one request chunk.

    Host container of device arrays (NOT a pytree — it crosses into jit
    as individual leaves). `take`/`inv_take`/`valid_cons` are the
    conservative compaction shared by the warp tier; `plan_row` is the
    fully baked hit-tier row (`_chunk_color(plan_row=...)` layout).
    """

    fp: str  # fingerprint of the reference rays (hit test)
    ref_o: np.ndarray  # (R, 3) reference rays, host-side
    ref_d: np.ndarray
    margin: float  # world-space coverage margin
    budget: int  # conservative buffer rows B
    inv_take: jnp.ndarray  # (B,) i32: flat sample index per buffer row
    take: jnp.ndarray  # (P,) i32: buffer row per flat sample
    valid_cons: jnp.ndarray  # (P,) bool: conservative active mask
    plan_row: tuple  # (buf_pts, buf_dirs, take, valid_exact, hi, hw, sh)
    nbytes: int


def build_warp_plan(
    occ: OccupancyGrid, rays_o, rays_d, rcfg, cfg, margin: float
) -> WarpPlan:
    """Bake one slot's plan: conservative compaction indices (warp tier)
    plus the exact-ray gather buffers/corner data (hit tier)."""
    ro = np.asarray(rays_o, np.float32).reshape(-1, 3)
    rd = np.asarray(rays_d, np.float32).reshape(-1, 3)
    n_s = rcfg.n_samples
    P = ro.shape[0] * n_s

    m_cons, pts = sample_active_mask(occ, ro, rd, rcfg, margin=margin)
    m_exact, _ = sample_active_mask(occ, ro, rd, rcfg)
    cons = m_cons.reshape(-1)
    idx = np.nonzero(cons)[0]
    count = idx.size
    B = int(min(P, max(128, -(-count // 128) * 128)))

    take = np.zeros(P, np.int32)
    take[idx] = np.arange(count, dtype=np.int32)
    inv_take = np.zeros(B, np.int32)
    inv_take[:count] = idx

    pts_unit = np.clip(pts + 0.5, 0.0, 1.0).reshape(-1, 3)
    dirs = np.broadcast_to(rd[:, None, :], (ro.shape[0], n_s, 3))
    dirs = np.ascontiguousarray(dirs.reshape(-1, 3))
    buf_pts = np.zeros((B, 3), np.float32)
    buf_pts[:count] = pts_unit[idx]
    buf_dirs = np.zeros((B, 3), np.float32)
    buf_dirs[:count] = dirs[idx]

    corner_fn, sh_fn = _bake_fns(cfg.hash, cfg.hash.n_levels, cfg.sh_degree)
    L = cfg.hash.n_levels
    hash_idx = np.zeros((L, B, 8), np.int32)
    hash_w = np.zeros((L, B, 8), np.float32)
    for l, (ci, cw) in enumerate(corner_fn(jnp.asarray(buf_pts))):
        hash_idx[l] = np.asarray(ci)
        hash_w[l] = np.asarray(cw)
    sh = np.asarray(sh_fn(jnp.asarray(buf_dirs)))

    take_j = jnp.asarray(take)
    plan_row = (
        jnp.asarray(buf_pts), jnp.asarray(buf_dirs), take_j,
        jnp.asarray(m_exact.reshape(-1)), jnp.asarray(hash_idx),
        jnp.asarray(hash_w), jnp.asarray(sh),
    )
    dev = (jnp.asarray(inv_take), take_j, jnp.asarray(cons)) + plan_row
    nbytes = ro.nbytes + rd.nbytes + sum(int(a.nbytes) for a in dev)
    return WarpPlan(
        fp=ray_fingerprint(ro, rd), ref_o=ro, ref_d=rd,
        margin=float(margin), budget=B,
        inv_take=dev[0], take=take_j, valid_cons=dev[2],
        plan_row=plan_row, nbytes=nbytes,
    )


@dataclasses.dataclass
class CellEntry:
    uses: int = 0
    plans: Dict[int, WarpPlan] = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in self.plans.values())


class PosePlanCache:
    """LRU of pose cells -> per-chunk WarpPlans, with pin-aware eviction.

    Keys are `(scene,) + pose_cell_key(...)`. A pinned key (in-flight
    request) is NEVER evicted — the cache runs over capacity instead —
    and pins may precede the entry itself (submit pins before the first
    render touches the cell). `drop_scene` removes even pinned cells:
    the scene's artifact left the device, the plans index nothing.
    """

    def __init__(self, max_entries: int = 128):
        self.max_entries = int(max_entries)
        self._entries: "OrderedDict[tuple, CellEntry]" = OrderedDict()
        self._pins: Dict[tuple, int] = {}
        self.hits = 0
        self.warps = 0
        self.misses = 0
        self.builds = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def nbytes(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    def note_use(self, key: tuple) -> CellEntry:
        """Touch (MRU) + use-count the cell, creating it if new."""
        entry = self._entries.get(key)
        if entry is None:
            entry = CellEntry()
            self._entries[key] = entry
            self._evict()
        else:
            self._entries.move_to_end(key)
        entry.uses += 1
        return entry

    def get(self, key: tuple) -> Optional[CellEntry]:
        return self._entries.get(key)

    def put_plan(self, key: tuple, seq: int, plan: WarpPlan) -> None:
        entry = self._entries.get(key)
        if entry is None:
            entry = CellEntry()
            self._entries[key] = entry
            self._evict()
        entry.plans[int(seq)] = plan
        self.builds += 1

    def pin(self, key: tuple) -> None:
        self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key: tuple) -> None:
        n = self._pins.get(key, 0) - 1
        if n > 0:
            self._pins[key] = n
        else:
            self._pins.pop(key, None)

    def pinned(self, key: tuple) -> bool:
        return self._pins.get(key, 0) > 0

    def drop_scene(self, scene: str) -> int:
        doomed = [k for k in self._entries if k[0] == scene]
        for k in doomed:
            del self._entries[k]
        return len(doomed)

    def stats(self) -> Dict[str, int]:
        return {
            "cells": len(self._entries),
            "bytes": self.nbytes,
            "hits": self.hits,
            "warps": self.warps,
            "misses": self.misses,
            "builds": self.builds,
            "evictions": self.evictions,
        }

    def _evict(self) -> None:
        # Oldest-out, skipping pinned keys; all-pinned -> run over budget.
        excess = len(self._entries) - self.max_entries
        if excess <= 0:
            return
        for k in list(self._entries):
            if excess <= 0:
                break
            if self.pinned(k):
                continue
            del self._entries[k]
            self.evictions += 1
            excess -= 1
