"""Differentiable volume rendering (the NeRF quadrature).

Given ray origins/directions, sample points along each ray, query the field,
and alpha-composite:  alpha_i = 1 - exp(-sigma_i * delta_i),
T_i = prod_{j<i}(1 - alpha_j),  w_i = T_i * alpha_i,
C = sum_i w_i c_i + (1 - sum_i w_i) * bg.

The exclusive cumprod is the compute pattern the alpha_composite Pallas
kernel re-implements as a sequential-grid scan (ref oracle = this module).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nerf.ngp import NGPConfig, NGPQuantSpec, ngp_apply


@dataclasses.dataclass(frozen=True)
class RenderConfig:
    n_samples: int = 32
    near: float = 0.2
    far: float = 2.5
    white_bg: bool = True
    stratified: bool = True  # jitter samples during training


def composite(
    sigma: jnp.ndarray,  # (R, S)
    rgb: jnp.ndarray,  # (R, S, 3)
    t: jnp.ndarray,  # (R, S) sample distances
    white_bg: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Alpha compositing. Returns (color (R,3), weights (R,S), depth (R,))."""
    delta = jnp.diff(t, axis=-1)
    delta = jnp.concatenate([delta, jnp.full_like(delta[..., :1], 1e10)], axis=-1)
    alpha = 1.0 - jnp.exp(-sigma * delta)
    trans = jnp.cumprod(1.0 - alpha + 1e-10, axis=-1)
    trans = jnp.concatenate([jnp.ones_like(trans[..., :1]), trans[..., :-1]], axis=-1)
    weights = trans * alpha  # (R, S)
    color = jnp.sum(weights[..., None] * rgb, axis=-2)
    depth = jnp.sum(weights * t, axis=-1)
    if white_bg:
        acc = jnp.sum(weights, axis=-1, keepdims=True)
        color = color + (1.0 - acc)
    return color, weights, depth


def render_rays(
    params: Dict,
    rays_o: jnp.ndarray,  # (R, 3)
    rays_d: jnp.ndarray,  # (R, 3) unit
    cfg: NGPConfig,
    rcfg: RenderConfig,
    spec: Optional[NGPQuantSpec] = None,
    key: Optional[jax.Array] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Render a batch of rays. Returns (color (R,3), depth (R,)).

    The scene is assumed to live in the unit cube [0,1]^3; sample points are
    clipped there before the field query (out-of-box samples contribute
    ~zero density because NGP learns the box).
    """
    n_rays = rays_o.shape[0]
    t = jnp.linspace(rcfg.near, rcfg.far, rcfg.n_samples)  # (S,)
    t = jnp.broadcast_to(t, (n_rays, rcfg.n_samples))
    if rcfg.stratified and key is not None:
        dt = (rcfg.far - rcfg.near) / rcfg.n_samples
        t = t + jax.random.uniform(key, t.shape) * dt

    pts = rays_o[:, None, :] + rays_d[:, None, :] * t[..., None]  # (R, S, 3)
    pts_unit = jnp.clip(pts + 0.5, 0.0, 1.0)  # scene in [-0.5,0.5] -> [0,1]

    flat_pts = pts_unit.reshape(-1, 3)
    flat_dirs = jnp.broadcast_to(rays_d[:, None, :], pts.shape).reshape(-1, 3)
    sigma, rgb = ngp_apply(params, flat_pts, flat_dirs, cfg, spec)
    sigma = sigma.reshape(n_rays, rcfg.n_samples)
    rgb = rgb.reshape(n_rays, rcfg.n_samples, 3)

    # Zero density outside the scene box so the clip above can't smear.
    inside = jnp.all((pts > -0.5) & (pts < 0.5), axis=-1)
    sigma = jnp.where(inside, sigma, 0.0)

    color, _, depth = composite(sigma, rgb, t, white_bg=rcfg.white_bg)
    return color, depth
