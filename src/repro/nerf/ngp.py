"""Instant-NGP model: hash encoding -> density MLP -> color MLP.

Structure (Muller et al. 2022, scaled down for CPU-feasible experiments):
  - hash encoding: L levels x F features
  - density MLP: enc -> hidden -> (1 sigma + geo_feat)
  - color MLP: (geo_feat ++ SH(view_dir)) -> hidden -> hidden -> 3 rgb

Quantization hooks: every linear layer takes per-layer weight bits and input
activation bits (the paper's 2L MLP decisions) and each hash level takes its
own bits (the paper's N hash decisions). Bits are *traced* f32 scalars so one
jit compilation serves every policy the DDPG agent proposes — this is what
makes episodic search cheap (no per-policy recompiles). A bit value >= 16 is
the full-precision sentinel.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nerf.hash_encoding import (
    HashEncodingConfig,
    hash_encode,
    init_hash_tables,
)
from repro.quant.linear_quant import (
    activation_qparams,
    weight_qparams,
)
from repro.quant.policy import QuantPolicy, QuantUnit, UnitKind
from repro.quant.qat import ste_fake_quant


@dataclasses.dataclass(frozen=True)
class NGPConfig:
    hash: HashEncodingConfig = HashEncodingConfig()
    hidden_dim: int = 32
    geo_feat_dim: int = 15
    color_hidden_dim: int = 32
    sh_degree: int = 3  # spherical-harmonic view encoding, (deg+1)^2 coeffs
    density_activation: str = "exp"  # 'exp' (trunc) or 'softplus'

    @property
    def sh_dim(self) -> int:
        return (self.sh_degree + 1) ** 2


# Ordered linear layers; order defines the quantization-unit walk.
def ngp_linear_names(cfg: NGPConfig) -> List[str]:
    return ["sigma/0", "sigma/1", "color/0", "color/1", "color/2"]


def _linear_dims(cfg: NGPConfig) -> Dict[str, Tuple[int, int]]:
    enc = cfg.hash.out_dim
    return {
        "sigma/0": (enc, cfg.hidden_dim),
        "sigma/1": (cfg.hidden_dim, 1 + cfg.geo_feat_dim),
        "color/0": (cfg.geo_feat_dim + cfg.sh_dim, cfg.color_hidden_dim),
        "color/1": (cfg.color_hidden_dim, cfg.color_hidden_dim),
        "color/2": (cfg.color_hidden_dim, 3),
    }


def init_ngp(key: jax.Array, cfg: NGPConfig) -> Dict:
    key, khash = jax.random.split(key)
    params: Dict = {"hash": init_hash_tables(khash, cfg.hash)}
    for name, (d_in, d_out) in _linear_dims(cfg).items():
        key, sub = jax.random.split(key)
        scale = float(np.sqrt(2.0 / d_in))
        params[name] = {
            "w": jax.random.normal(sub, (d_in, d_out), jnp.float32) * scale,
            "b": jnp.zeros((d_out,), jnp.float32),
        }
    return params


# ---------------------------------------------------------------------------
# Quantization spec: traced bit arrays + calibrated activation ranges.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NGPQuantSpec:
    """Per-unit bit widths as traced arrays (jit-stable across policies)."""

    hash_bits: jnp.ndarray  # (L,) f32
    weight_bits: jnp.ndarray  # (n_linear,) f32, order = ngp_linear_names
    act_bits: jnp.ndarray  # (n_linear,) f32
    act_ranges: jnp.ndarray  # (n_linear, 2) f32 calibrated (lo, hi)
    paper_exact: bool = True


# Traced through jit: bit arrays are data, paper_exact is static metadata.
jax.tree_util.register_dataclass(
    NGPQuantSpec,
    data_fields=["hash_bits", "weight_bits", "act_bits", "act_ranges"],
    meta_fields=["paper_exact"],
)


def no_quant_spec(cfg: NGPConfig) -> NGPQuantSpec:
    n_lin = len(ngp_linear_names(cfg))
    return NGPQuantSpec(
        hash_bits=jnp.full((cfg.hash.n_levels,), 32.0),
        weight_bits=jnp.full((n_lin,), 32.0),
        act_bits=jnp.full((n_lin,), 32.0),
        act_ranges=jnp.tile(jnp.asarray([[0.0, 1.0]]), (n_lin, 1)),
    )


def uniform_quant_spec(
    cfg: NGPConfig, bits: int, act_ranges: Optional[jnp.ndarray] = None
) -> NGPQuantSpec:
    n_lin = len(ngp_linear_names(cfg))
    if act_ranges is None:
        act_ranges = jnp.tile(jnp.asarray([[0.0, 1.0]]), (n_lin, 1))
    return NGPQuantSpec(
        hash_bits=jnp.full((cfg.hash.n_levels,), float(bits)),
        weight_bits=jnp.full((n_lin,), float(bits)),
        act_bits=jnp.full((n_lin,), float(bits)),
        act_ranges=act_ranges,
    )


def spec_from_policy(
    cfg: NGPConfig, policy: QuantPolicy, act_ranges: jnp.ndarray
) -> NGPQuantSpec:
    """Build the traced spec from a host-side QuantPolicy."""
    names = ngp_linear_names(cfg)
    hb = [0.0] * cfg.hash.n_levels
    wb = [32.0] * len(names)
    ab = [32.0] * len(names)
    for u in policy.units:
        if u.kind == UnitKind.HASH_LEVEL:
            hb[u.param_size] = float(u.bits)
        elif u.kind == UnitKind.WEIGHT:
            wb[names.index(u.name.rsplit(":", 1)[0])] = float(u.bits)
        elif u.kind == UnitKind.ACTIVATION:
            ab[names.index(u.name.rsplit(":", 1)[0])] = float(u.bits)
    return NGPQuantSpec(
        hash_bits=jnp.asarray(hb),
        weight_bits=jnp.asarray(wb),
        act_bits=jnp.asarray(ab),
        act_ranges=act_ranges,
    )


def make_quant_units(cfg: NGPConfig) -> List[QuantUnit]:
    """Episode walk order: hash levels first (coarse->fine), then for each
    MLP layer its activation then weight decision — Eqs. 1-2 metadata."""
    units: List[QuantUnit] = []
    i = 0
    for l in range(cfg.hash.n_levels):
        units.append(
            QuantUnit(
                name=f"hash/level_{l}",
                kind=UnitKind.HASH_LEVEL,
                layer_type=1,
                d_in=cfg.hash.n_features,
                d_out=cfg.hash.level_entries(l),
                param_size=l,  # l_i: level index per Eq. 2
                index=i,
            )
        )
        i += 1
    dims = _linear_dims(cfg)
    for name in ngp_linear_names(cfg):
        d_in, d_out = dims[name]
        units.append(
            QuantUnit(
                name=f"{name}:a",
                kind=UnitKind.ACTIVATION,
                layer_type=0,
                d_in=d_in,
                d_out=d_out,
                param_size=d_in * d_out,
                index=i,
            )
        )
        i += 1
        units.append(
            QuantUnit(
                name=f"{name}:w",
                kind=UnitKind.WEIGHT,
                layer_type=0,
                d_in=d_in,
                d_out=d_out,
                param_size=d_in * d_out,
                index=i,
            )
        )
        i += 1
    return units


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _maybe_quant_weight(w, bits, paper_exact):
    lo, hi = jnp.min(w), jnp.max(w)
    qp = weight_qparams(lo, hi, bits, paper_exact=paper_exact)
    q = ste_fake_quant(w, qp, symmetric=True)
    return jnp.where(bits >= 16.0, w, q)


def _maybe_quant_act(x, bits, lo, hi):
    qp = activation_qparams(lo, hi, bits)
    q = ste_fake_quant(x, qp, symmetric=False)
    return jnp.where(bits >= 16.0, x, q)


def _qlinear(
    params: Dict,
    x: jnp.ndarray,
    idx: int,
    spec: NGPQuantSpec,
    taps: Optional[Dict] = None,
    name: str = "",
) -> jnp.ndarray:
    if taps is not None:
        taps[name] = x  # pre-quantization input (calibration point)
    x = _maybe_quant_act(x, spec.act_bits[idx], spec.act_ranges[idx, 0], spec.act_ranges[idx, 1])
    w = _maybe_quant_weight(params["w"], spec.weight_bits[idx], spec.paper_exact)
    return x @ w + params["b"]


def sh_encode(dirs: jnp.ndarray, degree: int) -> jnp.ndarray:
    """Real spherical harmonics basis up to `degree` (inclusive), (P, (d+1)^2).

    Hard-coded closed forms up to degree 4 (the Instant-NGP default).
    """
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    if degree >= 2:  # shared monomials for the whole degree ladder
        xx, yy, zz = x * x, y * y, z * z
        xy, yz, xz = x * y, y * z, x * z
    out = [jnp.full_like(x, 0.28209479177387814)]
    if degree >= 1:
        out += [-0.48860251190291987 * y, 0.48860251190291987 * z, -0.48860251190291987 * x]
    if degree >= 2:
        out += [
            1.0925484305920792 * xy,
            -1.0925484305920792 * yz,
            0.94617469575755997 * zz - 0.31539156525251999,
            -1.0925484305920792 * xz,
            0.54627421529603959 * (xx - yy),
        ]
    if degree >= 3:
        out += [
            0.59004358992664352 * y * (-3.0 * xx + yy),
            2.8906114426405538 * x * y * z,
            0.45704579946446572 * y * (1.0 - 5.0 * zz),
            0.3731763325901154 * z * (5.0 * zz - 3.0),
            0.45704579946446572 * x * (1.0 - 5.0 * zz),
            1.4453057213202769 * z * (xx - yy),
            0.59004358992664352 * x * (-xx + 3.0 * yy),
        ]
    if degree >= 4:
        out += [
            2.5033429417967046 * xy * (xx - yy),
            1.7701307697799304 * yz * (-3.0 * xx + yy),
            0.94617469575756008 * xy * (7.0 * zz - 1.0),
            0.66904654355728921 * yz * (3.0 - 7.0 * zz),
            -3.1735664074561294 * zz + 3.7024941420321507 * zz * zz + 0.31735664074561293,
            0.66904654355728921 * xz * (3.0 - 7.0 * zz),
            0.47308734787878004 * (xx - yy) * (7.0 * zz - 1.0),
            1.7701307697799304 * xz * (-xx + 3.0 * yy),
            0.62583573544917614 * (xx * xx - 6.0 * xx * yy + yy * yy),
        ]
    return jnp.stack(out, axis=-1)


def ngp_apply(
    params: Dict,
    points: jnp.ndarray,
    dirs: jnp.ndarray,
    cfg: NGPConfig,
    spec: Optional[NGPQuantSpec] = None,
    return_taps: bool = False,
):
    """Query the field. points (P,3) in [0,1], dirs (P,3) unit. Returns
    (sigma (P,), rgb (P,3)) — plus a dict of pre-quant linear inputs when
    return_taps=True (for activation-range calibration)."""
    if spec is None:
        spec = no_quant_spec(cfg)
    taps: Optional[Dict] = {} if return_taps else None

    enc = hash_encode(
        params["hash"], points, cfg.hash, level_bits=spec.hash_bits,
        paper_exact=spec.paper_exact,
    )

    h = _qlinear(params["sigma/0"], enc, 0, spec, taps, "sigma/0")
    h = jax.nn.relu(h)
    h = _qlinear(params["sigma/1"], h, 1, spec, taps, "sigma/1")
    raw_sigma, geo = h[..., 0], h[..., 1:]

    if cfg.density_activation == "exp":
        sigma = jnp.exp(jnp.clip(raw_sigma, -10.0, 10.0))
    else:
        sigma = jax.nn.softplus(raw_sigma)

    sh = sh_encode(dirs, cfg.sh_degree)
    c = jnp.concatenate([geo, sh], axis=-1)
    c = jax.nn.relu(_qlinear(params["color/0"], c, 2, spec, taps, "color/0"))
    c = jax.nn.relu(_qlinear(params["color/1"], c, 3, spec, taps, "color/1"))
    rgb = jax.nn.sigmoid(_qlinear(params["color/2"], c, 4, spec, taps, "color/2"))
    if return_taps:
        return sigma, rgb, taps
    return sigma, rgb
