"""arctic-480b [moe] — 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig, MoEConfig

_SKIP_LONG = (
    "long_500k skipped: pure full-attention arch (assignment rule)"
)


def spec() -> ArchSpec:
    model = ModelConfig(
        name="arctic-480b",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab_size=32_000,
        ffn_type="swiglu",
        pattern="moe",
        moe=MoEConfig(
            n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True
        ),
    )
    smoke = ModelConfig(
        name="arctic-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=512,
        ffn_type="swiglu",
        pattern="moe",
        dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96, dense_residual=True),
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="arctic-480b",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 16},
        moment_dtype="int8",  # 8-bit Adam: 480B params on 16 GB/chip HBM
        skips={"long_500k": _SKIP_LONG},
        source="hf:Snowflake/snowflake-arctic-base",
    )
