"""qwen2-7b [dense] — GQA, QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

_SKIP_LONG = "long_500k skipped: pure full-attention arch (assignment rule)"


def spec() -> ArchSpec:
    model = ModelConfig(
        name="qwen2-7b",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152_064,
        ffn_type="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
    smoke = ModelConfig(
        name="qwen2-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        ffn_type="swiglu",
        qkv_bias=True,
        dtype="float32",
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="qwen2-7b",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 64},
        skips={"long_500k": _SKIP_LONG},
        source="arXiv:2407.10671",
    )
