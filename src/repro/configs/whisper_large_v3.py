"""whisper-large-v3 [audio] — enc-dec; conv frontend stubbed (input_specs
provides precomputed (B, 1500, d) frame embeddings). 32 encoder + 32
decoder layers, learned positions. [arXiv:2212.04356; unverified]

Enc-dec (not encoder-only), so decode_32k runs: 32k self-KV decoded tokens
+ static cross-KV from the encoder. long_500k skipped (full attention).
"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

_SKIP_LONG = "long_500k skipped: pure full-attention arch (assignment rule)"


def spec() -> ArchSpec:
    model = ModelConfig(
        name="whisper-large-v3",
        n_layers=32,  # decoder
        encoder_layers=32,
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab_size=51_866,
        ffn_type="gelu",
        norm_type="layernorm",
        pattern="encdec",
        pos_embed="learned",
        max_pos_embed=32_768,
        max_source_len=1500,
        embed_frontend="stub_frames",
    )
    smoke = ModelConfig(
        name="whisper-smoke",
        n_layers=2,
        encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        ffn_type="gelu",
        norm_type="layernorm",
        pattern="encdec",
        pos_embed="learned",
        max_pos_embed=128,
        max_source_len=24,
        embed_frontend="stub_frames",
        dtype="float32",
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="whisper-large-v3",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 32},
        skips={"long_500k": _SKIP_LONG},
        source="arXiv:2212.04356",
    )
