"""xlstm-350m [ssm] — alternating mLSTM/sLSTM blocks, no separate FFN
(d_ff=0). Constant-size recurrent state -> long_500k runs.
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig


def spec() -> ArchSpec:
    model = ModelConfig(
        name="xlstm-350m",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50_304,
        pattern="xlstm",
    )
    smoke = ModelConfig(
        name="xlstm-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=512,
        pattern="xlstm",
        dtype="float32",
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="xlstm-350m",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 64},
        source="arXiv:2405.04517",
        # no_tp=True (pure DP, replicated weights) was measured and REFUTED
        # for this arch: it cuts prefill collectives 84x but the idle model
        # axis duplicates compute 16x, so train regresses 10.6s -> 31s and
        # prefill 54s -> 91s (EXPERIMENTS.md §Perf hillclimb 3). Keep TP.
        no_tp=False,
    )
