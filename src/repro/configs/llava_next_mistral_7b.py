"""llava-next-mistral-7b [vlm] — mistral backbone, anyres tiling stubbed as
precomputed patch embeddings (assignment: frontend is a STUB; input_specs
provides (B, P, d) patch embeddings prepended to the text tokens).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

_SKIP_LONG = "long_500k skipped: pure full-attention arch (assignment rule)"


def spec() -> ArchSpec:
    model = ModelConfig(
        name="llava-next-mistral-7b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32_000,
        ffn_type="swiglu",
        rope_theta=1_000_000.0,
        embed_frontend="prefix_patches",
        n_prefix_patches=576,  # one 24x24 anyres base tile
    )
    smoke = ModelConfig(
        name="llava-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        ffn_type="swiglu",
        dtype="float32",
        embed_frontend="prefix_patches",
        n_prefix_patches=8,
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="llava-next-mistral-7b",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 32},
        skips={"long_500k": _SKIP_LONG},
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )
