"""Config substrate: shape grid, ArchSpec, input specs for the dry-run.

Every assigned architecture file exports `spec() -> ArchSpec` with the
exact published config plus a reduced `smoke` config of the same family
(used by per-arch CPU smoke tests; the full config is exercised only via
.lower()/.compile() with ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    model: ModelConfig
    smoke: ModelConfig
    # Training microbatch (global sequences per accumulation step), per shape.
    microbatch: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: {"train_4k": 32}
    )
    moment_dtype: str = "float32"  # adam moments; "int8" = 8-bit Adam
    # shape name -> reason, for assignment-recorded skips
    skips: Mapping[str, str] = dataclasses.field(default_factory=dict)
    source: str = ""
    # Small models: disable tensor parallelism (replicate weights, pure DP)
    no_tp: bool = False

    def runs(self, shape: str) -> bool:
        return shape not in self.skips


def _frontend_extras(
    model: ModelConfig, batch: int, seq: int
) -> Tuple[Dict[str, jax.ShapeDtypeStruct], int]:
    """Modality-stub inputs + number of text tokens."""
    extras: Dict[str, jax.ShapeDtypeStruct] = {}
    text = seq
    if model.embed_frontend == "prefix_patches":
        p = model.n_prefix_patches
        extras["patches"] = jax.ShapeDtypeStruct(
            (batch, p, model.d_model), model.param_dtype
        )
        text = seq - p
    elif model.embed_frontend == "stub_frames":
        extras["frames"] = jax.ShapeDtypeStruct(
            (batch, model.max_source_len, model.d_model), model.param_dtype
        )
    return extras, text


def train_input_specs(
    model: ModelConfig, shape: ShapeSpec, microbatch: Optional[int] = None
) -> Dict[str, jax.ShapeDtypeStruct]:
    """One accumulation microbatch (the train_step scans over these)."""
    b = microbatch or shape.global_batch
    extras, text = _frontend_extras(model, b, shape.seq_len)
    return {"tokens": jax.ShapeDtypeStruct((b, text), jnp.int32), **extras}


def prefill_input_specs(
    model: ModelConfig, shape: ShapeSpec
) -> Dict[str, jax.ShapeDtypeStruct]:
    b = shape.global_batch
    extras, text = _frontend_extras(model, b, shape.seq_len)
    return {"tokens": jax.ShapeDtypeStruct((b, text), jnp.int32), **extras}


def decode_input_specs(model: ModelConfig, shape: ShapeSpec):
    """(tokens, pos) for decode_step; the cache comes from cache_specs."""
    return {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
