"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig, MoEConfig

_SKIP_LONG = (
    "long_500k skipped: pure full-attention arch; 500k dense KV is "
    "infeasible (assignment rule, DESIGN.md §4)"
)


def spec() -> ArchSpec:
    model = ModelConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,
        vocab_size=151_936,
        ffn_type="swiglu",
        pattern="moe",
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    )
    smoke = ModelConfig(
        name="qwen3-moe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=512,
        ffn_type="swiglu",
        pattern="moe",
        dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96),
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="qwen3-moe-235b-a22b",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 32},
        skips={"long_500k": _SKIP_LONG},
        source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)",
    )
