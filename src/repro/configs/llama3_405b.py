"""llama3-405b [dense] — GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

_SKIP_LONG = "long_500k skipped: pure full-attention arch (assignment rule)"


def spec() -> ArchSpec:
    model = ModelConfig(
        name="llama3-405b",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        d_ff=53248,
        vocab_size=128_256,
        ffn_type="swiglu",
        rope_theta=500_000.0,
    )
    smoke = ModelConfig(
        name="llama3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        ffn_type="swiglu",
        dtype="float32",
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="llama3-405b",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 16},
        moment_dtype="int8",
        skips={"long_500k": _SKIP_LONG},
        source="arXiv:2407.21783",
    )
