"""nemotron-4-340b [dense] — GQA, squared-ReLU FFN. [arXiv:2402.16819;
unverified]. The non-negative relu^2 activations are exactly the asymmetric
activation-quant case (Eqs. 6-7) — see DESIGN.md §4."""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

_SKIP_LONG = "long_500k skipped: pure full-attention arch (assignment rule)"


def spec() -> ArchSpec:
    model = ModelConfig(
        name="nemotron-4-340b",
        n_layers=96,
        d_model=18432,
        n_heads=96,
        n_kv_heads=8,
        d_ff=73728,
        vocab_size=256_000,
        ffn_type="relu2",
        norm_type="layernorm",
    )
    smoke = ModelConfig(
        name="nemotron-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        ffn_type="relu2",
        norm_type="layernorm",
        dtype="float32",
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="nemotron-4-340b",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 16},
        moment_dtype="int8",
        skips={"long_500k": _SKIP_LONG},
        source="arXiv:2402.16819",
    )
