"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2
on every other layer. Runs long_500k: the Mamba state is O(1) and the four
attention layers' 500k KV shards over the model axis (flash-decoding).
[arXiv:2403.19887; hf]"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig, MoEConfig


def spec() -> ArchSpec:
    model = ModelConfig(
        name="jamba-v0.1-52b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65_536,
        ffn_type="swiglu",
        pattern="jamba",
        attn_every=8,  # 1 attention : 7 mamba
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        moe=MoEConfig(n_experts=16, top_k=2, every_n_layers=2),
    )
    smoke = ModelConfig(
        name="jamba-smoke",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        ffn_type="swiglu",
        pattern="jamba",
        attn_every=4,
        ssm_state=4,
        ssm_conv=4,
        ssm_expand=2,
        dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, every_n_layers=2),
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="jamba-v0.1-52b",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 16},
        source="arXiv:2403.19887",
    )
