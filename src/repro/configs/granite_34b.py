"""granite-34b [dense] — llama-arch, MQA (kv=1), code. [arXiv:2405.04324; hf]"""
from repro.configs.base import ArchSpec
from repro.models.common import ModelConfig

_SKIP_LONG = "long_500k skipped: pure full-attention arch (assignment rule)"


def spec() -> ArchSpec:
    model = ModelConfig(
        name="granite-34b",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        d_ff=24576,
        vocab_size=49_152,
        ffn_type="swiglu",
    )
    smoke = ModelConfig(
        name="granite-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=512,
        ffn_type="swiglu",
        dtype="float32",
        n_embed_bands=4,
    )
    return ArchSpec(
        arch_id="granite-34b",
        model=model,
        smoke=smoke,
        microbatch={"train_4k": 32},
        skips={"long_500k": _SKIP_LONG},
        source="arXiv:2405.04324",
    )
