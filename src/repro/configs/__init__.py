"""Arch registry: --arch <id> -> ArchSpec (exact published configs)."""
from typing import Dict, List

from repro.configs.base import SHAPES, ArchSpec, ShapeSpec
from repro.configs.base import (
    decode_input_specs,
    prefill_input_specs,
    train_input_specs,
)

_MODULES = {
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "arctic-480b": "repro.configs.arctic_480b",
    "llama3-405b": "repro.configs.llama3_405b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "granite-34b": "repro.configs.granite_34b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "xlstm-350m": "repro.configs.xlstm_350m",
}

ARCH_IDS: List[str] = list(_MODULES)
_CACHE: Dict[str, ArchSpec] = {}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    if arch_id not in _CACHE:
        import importlib

        _CACHE[arch_id] = importlib.import_module(_MODULES[arch_id]).spec()
    return _CACHE[arch_id]


def all_cells():
    """Every (arch, shape) pair, with assignment-recorded skips excluded."""
    for aid in ARCH_IDS:
        spec = get_arch(aid)
        for shape in SHAPES.values():
            if spec.runs(shape.name):
                yield spec, shape


__all__ = [
    "SHAPES",
    "ArchSpec",
    "ShapeSpec",
    "ARCH_IDS",
    "get_arch",
    "all_cells",
    "train_input_specs",
    "prefill_input_specs",
    "decode_input_specs",
]
