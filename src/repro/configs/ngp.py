"""The paper's own model: Instant-NGP configs (full + CPU-scale).

`paper()` is the Instant-NGP configuration the HERO paper quantizes
(16 hash levels, F=2, T=2^19, two small MLPs). `cpu_scale()` is the
reduced-but-same-family config the runnable experiments use on this
container (the RL search, baselines, and Table II/III reproductions) —
the full config is exercised via the simulator and the dry-run only.
"""
from repro.nerf.hash_encoding import HashEncodingConfig
from repro.nerf.ngp import NGPConfig
from repro.nerf.render import RenderConfig
from repro.nerf.train import TrainConfig


def paper() -> NGPConfig:
    return NGPConfig(
        hash=HashEncodingConfig(
            n_levels=16,
            n_features=2,
            log2_table_size=19,
            base_resolution=16,
            max_resolution=2048,
        ),
        hidden_dim=64,
        geo_feat_dim=15,
        color_hidden_dim=64,
        sh_degree=4,
    )


def cpu_scale() -> NGPConfig:
    return NGPConfig(
        hash=HashEncodingConfig(
            n_levels=8,
            n_features=2,
            log2_table_size=11,
            base_resolution=4,
            max_resolution=64,
        ),
        hidden_dim=32,
        geo_feat_dim=15,
        color_hidden_dim=32,
        sh_degree=3,
    )


def cpu_render() -> RenderConfig:
    return RenderConfig(n_samples=32)


def cpu_train() -> TrainConfig:
    return TrainConfig(steps=300, batch_rays=512, lr=5e-3)
