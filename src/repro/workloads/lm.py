"""LM quantization workload: HERO's closed loop on transformer decode.

The search space is the DESIGN.md §4 layout — per-embedding-band bits
(the hash-level analogue: geometric Zipf row-bands, hot tokens first)
plus per-layer (weight, activation) bits broadcast over the layer's
`N_GROUPS` quant groups:

  walk order:  [band_0 .. band_{B-1}, (w_0, a_0), .., (w_{L-1}, a_{L-1})]
  n_units   =  n_embed_bands + 2 * total_layers

Quality is a REAL forward pass: next-token cross entropy from
`repro.models.lm.loss_fn` over deterministic `TokenPipeline` batches,
fake-quantized under the policy's `LMQuantSpec`. The proxy scores one
fixed batch, vmapped over the population's bit arrays (one compile
serves every policy — bits ride through the scan as data); the
full-fidelity eval averages `eval_batches` held-out batches. Both are
mapped to a dB-like scale, `-10*log10(excess loss)` vs the
full-precision loss on the same tokens, so Eq. 8 rewards and the
frontier's quality axis read like the NeRF PSNR deltas.

Cost comes from the registered `roofline-lm` `HardwareTarget`
(`repro.hero.targets.LMRooflineTarget`): weight-bound decode,
seconds/token = streamed bytes over HBM bandwidth, with a pure-jnp
vmappable form so `distributed.population` sharding and the elastic
orchestrator drive this workload unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action import action_to_bits
from repro.core.batched_env import PopulationEval
from repro.core.env import EpisodeResult
from repro.core.reward import hero_reward
from repro.workloads.base import PolicyShape, WorkloadBundle

# Excess-loss floor of the dB mapping: quality saturates at
# -10*log10(2*LOSS_FLOOR) ~ 37 dB when the quantized loss meets the
# full-precision loss (numerically: at 8 bits on the smoke configs).
LOSS_FLOOR = 1e-4


def quality_db(loss, base_loss):
    """Excess next-token loss -> dB-like quality (vectorized)."""
    excess = np.maximum(np.asarray(loss, np.float64) - base_loss, LOSS_FLOOR)
    return -10.0 * np.log10(excess + LOSS_FLOOR)


@dataclasses.dataclass(frozen=True)
class LMEnvConfig:
    """Env-building knobs of the LM workload (the `SceneScale` analogue;
    rides in the checkpoint fingerprint via `LMWorkload.describe`)."""

    seq_len: int = 64
    global_batch: int = 4
    eval_batches: int = 2  # full-fidelity eval averages this many batches
    latency_target: Optional[float] = None  # seconds/token; None = free
    b_min: int = 2
    b_max: int = 8
    lam: float = 0.1  # Eq. 8 reward scale


class LMQuantEnv:
    """Scalar LM quantization env: the `NGPQuantEnv` surface
    (`hero_population_search`'s duck-typed contract) over real LM forward
    passes and the roofline decode cost model."""

    def __init__(
        self,
        arch: str,
        ecfg: LMEnvConfig = LMEnvConfig(),
        seed: int = 0,
        target=None,
    ):
        from repro.configs import get_arch
        from repro.data import TokenPipeline, TokenPipelineConfig
        from repro.hero.targets import resolve_target
        from repro.models import lm

        self._lm = lm
        self.arch = arch
        self.cfg = get_arch(arch).smoke
        self.ecfg = ecfg
        self.seed = seed
        self.target = resolve_target(
            target if target is not None else "roofline-lm"
        )
        try:
            self.workload = self.target.build_workload(self.cfg)
        except TypeError:
            raise ValueError(
                f"hardware target {self.target.name!r} cannot score LM "
                "workloads (its build_workload wants a renderer trace); "
                "use 'roofline-lm' or another LM-family target"
            ) from None

        self.n_layers = lm.total_layers(self.cfg)
        self.n_bands = self.cfg.n_embed_bands
        self.unit_labels: Tuple[str, ...] = tuple(
            [f"embed_band{i}" for i in range(self.n_bands)]
            + [f"layer{l}:{k}" for l in range(self.n_layers) for k in ("w", "a")]
        )

        self.params = lm.init_params(self.cfg, jax.random.PRNGKey(seed))
        pipe = TokenPipeline(TokenPipelineConfig(
            vocab_size=self.cfg.vocab_size, seq_len=ecfg.seq_len,
            global_batch=ecfg.global_batch, seed=seed,
        ))
        # Batch 0 is the proxy's fixed scoring batch; the next
        # `eval_batches` are the held-out full-fidelity set.
        self.proxy_batch = {"tokens": jnp.asarray(pipe.batch())}
        self._eval_batches = [
            {"tokens": jnp.asarray(pipe.batch())}
            for _ in range(ecfg.eval_batches)
        ]

        self._loss = jax.jit(
            lambda p, b, s: lm.loss_fn(p, b, self.cfg, spec=s)[0]
        )
        self.base_loss_proxy = float(
            lm.loss_fn(self.params, self.proxy_batch, self.cfg)[0]
        )
        self.base_loss_full = float(np.mean([
            float(lm.loss_fn(self.params, b, self.cfg)[0])
            for b in self._eval_batches
        ]))

        # 8-bit anchors through the target (Eq. 8 cost denominator) and
        # the full eval (Eq. 8 quality reference for evaluate_bits).
        base = self.target.baseline(self.workload, 8)
        self.original_cost = float(base["total_cycles"])
        self.psnr_org = float(quality_db(
            self._full_loss(np.full(self.n_units, 8)), self.base_loss_full
        ))

        # Exact seconds/bit per unit: the roofline is linear in the bits
        # (weight stream only; activation units are cost-free), so greedy
        # budget enforcement predicts its own outcome exactly.
        d = self.workload.d_model
        w_slope = float(np.sum(self.workload.group_elems)) / 8.0
        slopes = np.zeros(self.n_units, np.float64)
        slopes[: self.n_bands] = (
            np.asarray(self.workload.band_rows, np.float64) * d / 8.0
        )
        slopes[self.n_bands :: 2] = w_slope
        self._latency_slopes = slopes / self.target.hw.hbm_bw

    # ------------------------------------------------------------------
    # Policy layout
    # ------------------------------------------------------------------
    @property
    def n_units(self) -> int:
        return self.n_bands + 2 * self.n_layers

    def bits_to_arrays(
        self, bits_batch: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(K, n_units) walk-order bits -> (embed (K,B), weight (K,L,G),
        activation (K,L,G)) spec arrays; per-layer bits broadcast over the
        layer's quant groups."""
        bb = np.asarray(bits_batch, np.float32)
        assert bb.ndim == 2 and bb.shape[1] == self.n_units, bb.shape
        G = self._lm.N_GROUPS
        eb = bb[:, : self.n_bands]
        rest = bb[:, self.n_bands :].reshape(bb.shape[0], self.n_layers, 2)
        wb = np.repeat(rest[:, :, 0:1], G, axis=2)
        ab = np.repeat(rest[:, :, 1:2], G, axis=2)
        return eb, wb, ab

    def _spec(self, bits: Sequence[int]):
        eb, wb, ab = self.bits_to_arrays(np.asarray(bits)[None, :])
        return self._lm.LMQuantSpec(
            embed_bits=jnp.asarray(eb[0]),
            w_bits=jnp.asarray(wb[0]),
            a_bits=jnp.asarray(ab[0]),
        )

    # ------------------------------------------------------------------
    # Observations (7-dim, DDPGConfig.obs_dim)
    # ------------------------------------------------------------------
    def observation(self, unit_index: int, prev_action: float) -> np.ndarray:
        i = unit_index
        if i < self.n_bands:
            kind, depth = 0, i / max(self.n_bands, 1)
        else:
            j = i - self.n_bands
            kind = 1 if j % 2 == 0 else 2
            depth = (j // 2) / max(self.n_layers, 1)
        return np.asarray([
            1.0, i / self.n_units, float(prev_action),
            float(kind == 0), float(kind == 1), float(kind == 2),
            depth,
        ], np.float32)

    def actions_to_bits(self, actions: Sequence[float]) -> List[int]:
        return [
            action_to_bits(a, self.ecfg.b_min, self.ecfg.b_max)
            for a in actions
        ]

    # ------------------------------------------------------------------
    # Cost + constraint enforcement
    # ------------------------------------------------------------------
    def cost_seconds(self, bits: Sequence[int]) -> float:
        """Seconds/token of one policy through the target (scalar path)."""
        eb, wb, ab = self.bits_to_arrays(np.asarray(bits)[None, :])
        r = self.target.simulate(self.workload, eb[0], wb[0], ab[0])
        return float(r["total_cycles"])

    _UNSET = object()

    def enforce_latency_target(
        self, bits: List[int], target=_UNSET
    ) -> List[int]:
        """Greedy bit reduction until the budget is met: biggest
        seconds/bit first (same shape as the NGP env's enforcement; here
        the slopes are exact, so one predicted sweep is one real sweep)."""
        if target is LMQuantEnv._UNSET:
            target = self.ecfg.latency_target
        if target is None:
            return list(bits)
        bits = list(bits)
        lat = self.cost_seconds(bits)
        guard = 0
        while lat > target and guard < 8 * len(bits):
            order = np.argsort(-self._latency_slopes)
            changed = False
            predicted = lat
            for i in order:
                if predicted <= target:
                    break
                if bits[i] > self.ecfg.b_min and self._latency_slopes[i] > 0:
                    bits[i] -= 1
                    predicted -= self._latency_slopes[i]
                    changed = True
            if not changed:
                break
            lat = self.cost_seconds(bits)
            guard += 1
        return bits

    # ------------------------------------------------------------------
    # Full-fidelity evaluation
    # ------------------------------------------------------------------
    def _full_loss(self, bits: Sequence[int]) -> float:
        spec = self._spec(bits)
        return float(np.mean([
            float(self._loss(self.params, b, spec))
            for b in self._eval_batches
        ]))

    def evaluate_bits(
        self, bits: Sequence[int], finetune_steps: Optional[int] = None
    ) -> EpisodeResult:
        """Exact quality over the held-out eval batches (`finetune_steps`
        is accepted for interface parity and ignored — there is no QAT
        pass in this workload)."""
        t0 = time.time()
        bits = list(bits)
        loss = self._full_loss(bits)
        psnr = float(quality_db(loss, self.base_loss_full))
        eb, wb, ab = self.bits_to_arrays(np.asarray(bits)[None, :])
        sim = self.target.simulate(self.workload, eb[0], wb[0], ab[0])
        lat = float(sim["total_cycles"])
        reward = hero_reward(psnr, float(self.psnr_org), lat,
                             self.original_cost, lam=self.ecfg.lam)
        return EpisodeResult(
            policy=None,
            bits=bits,
            psnr=psnr,
            latency_cycles=lat,
            model_bytes=float(sim["model_bytes"]),
            reward=reward,
            fqr=float(np.mean(bits)),
            wall_seconds=time.time() - t0,
        )


class LMBatchedEnv:
    """Population-evaluation facade over an `LMQuantEnv` — the
    `BatchedQuantEnv` surface: one vmapped loss proxy + the target's
    batched cost model, device-sharded over a ("pop",) mesh when the host
    has more than one device."""

    def __init__(self, env: LMQuantEnv, sharded: Optional[bool] = None):
        from repro.distributed.population import auto_shard, shard_population

        self.env = env
        self.bsim = env.target.batched(env.workload)

        lm = env._lm
        cfg = env.cfg
        proxy_batch = env.proxy_batch

        def _proxy_loss(params, eb, wb, ab):
            spec = lm.LMQuantSpec(embed_bits=eb, w_bits=wb, a_bits=ab)
            return lm.loss_fn(params, proxy_batch, cfg, spec=spec)[0]

        lat_fn = (
            self.bsim.vmappable() if hasattr(self.bsim, "vmappable") else None
        )
        self.sharded = auto_shard() if sharded is None else bool(sharded)
        if self.sharded and lat_fn is None:
            self.sharded = False
        if self.sharded:
            self._loss_batch = shard_population(
                jax.vmap(_proxy_loss, in_axes=(None, 0, 0, 0)),
                broadcast_argnums=(0,),
            )
            self._lat_sharded = shard_population(jax.vmap(lat_fn))
        else:
            self._loss_batch = jax.jit(
                jax.vmap(_proxy_loss, in_axes=(None, 0, 0, 0))
            )
            self._lat_sharded = None

        eight = np.full((1, env.n_units), 8.0, np.float32)
        self.psnr_org_proxy = float(self.proxy_quality(env.params, eight)[0])

    # ------------------------------------------------------------------
    @property
    def n_units(self) -> int:
        return self.env.n_units

    def bits_to_arrays(self, bits_batch):
        return self.env.bits_to_arrays(bits_batch)

    def proxy_quality(self, params, bits_batch: np.ndarray) -> np.ndarray:
        """(K,) dB-like quality of the proxy batch under each policy."""
        eb, wb, ab = self.bits_to_arrays(bits_batch)
        loss = self._loss_batch(
            params, jnp.asarray(eb), jnp.asarray(wb), jnp.asarray(ab)
        )
        return quality_db(loss, self.env.base_loss_proxy)

    def simulate_batch(self, bits_batch: np.ndarray) -> Dict[str, np.ndarray]:
        """Cost metrics only ((K,) arrays), no forward passes."""
        eb, wb, ab = self.bits_to_arrays(bits_batch)
        if self._lat_sharded is not None:
            out = self._lat_sharded(
                jnp.asarray(eb), jnp.asarray(wb), jnp.asarray(ab)
            )
            return {k: np.asarray(v) for k, v in out.items()}
        return self.bsim.simulate_batch(eb, wb, ab)

    # ------------------------------------------------------------------
    def evaluate_population(
        self,
        bits_batch: Sequence[Sequence[int]],
        latency_target: Optional[float] = None,
    ) -> PopulationEval:
        t0 = time.time()
        bb = np.asarray(bits_batch, np.int32)
        env = self.env
        sim = self.simulate_batch(bb)
        psnr = self.proxy_quality(env.params, bb)
        latency = np.asarray(sim["total_cycles"], np.float64)
        reward = np.asarray([
            hero_reward(
                float(psnr[i]), self.psnr_org_proxy, float(latency[i]),
                env.original_cost, lam=env.ecfg.lam,
            )
            for i in range(bb.shape[0])
        ])
        return PopulationEval(
            bits=bb,
            psnr=psnr,
            latency_cycles=latency,
            model_bytes=np.asarray(sim["model_bytes"], np.float64),
            reward=reward,
            fqr=bb.mean(axis=1).astype(np.float64),
            wall_seconds=time.time() - t0,
            feasible=(
                latency <= latency_target
                if latency_target is not None else None
            ),
        )


class LMWorkload:
    kind = "lm"
    default_hardware = "roofline-lm"

    def __init__(self, ecfg: Optional[LMEnvConfig] = None):
        self.ecfg = ecfg if ecfg is not None else LMEnvConfig()

    def _resolve_ecfg(self, scale) -> LMEnvConfig:
        # `scale` arrives as whatever ClosedLoopConfig.scale holds; a
        # SceneScale (the NeRF-shaped default) means "use the workload's
        # own knobs", an LMEnvConfig overrides them.
        return scale if isinstance(scale, LMEnvConfig) else self.ecfg

    def policy_shape(self, case: str, scale=None) -> PolicyShape:
        from repro.configs import get_arch
        from repro.models.lm import total_layers

        cfg = get_arch(case).smoke
        n_layers = total_layers(cfg)
        ecfg = self._resolve_ecfg(scale)
        labels = tuple(
            [f"embed_band{i}" for i in range(cfg.n_embed_bands)]
            + [f"layer{l}:{k}" for l in range(n_layers) for k in ("w", "a")]
        )
        return PolicyShape(
            n_units=cfg.n_embed_bands + 2 * n_layers,
            b_min=ecfg.b_min, b_max=ecfg.b_max, labels=labels,
        )

    def build_bundle(
        self,
        case: str,
        *,
        scale=None,
        seed: int = 0,
        sharded: Optional[bool] = None,
        hardware=None,
    ) -> WorkloadBundle:
        env = LMQuantEnv(
            case, self._resolve_ecfg(scale), seed=seed,
            target=hardware if hardware is not None else self.default_hardware,
        )
        benv = LMBatchedEnv(env, sharded=sharded)
        eight = benv.simulate_batch(np.full((1, env.n_units), 8, np.int32))
        return WorkloadBundle(
            scene=case,
            env=env,
            benv=benv,
            baseline_latency=float(env.original_cost),
            baseline_psnr=float(benv.psnr_org_proxy),
            baseline_bytes=float(eight["model_bytes"][0]),
        )

    def describe(self) -> dict:
        return {"kind": self.kind, "config": dataclasses.asdict(self.ecfg)}
