"""NeRF scene workload: the original HERO task behind the protocol.

A pure adapter — `build_bundle` IS `repro.core.closed_loop
.build_scene_bundle`, called with exactly the arguments the pre-protocol
`HeroSearchRun.bundle` passed, so frontiers and checkpoint fingerprints
are byte-identical to the sequential path (pinned by
tests/test_workloads.py).
"""
from __future__ import annotations

from typing import Any, Optional

from repro.workloads.base import PolicyShape, WorkloadBundle


class NerfSceneWorkload:
    kind = "nerf"
    default_hardware = "neurex"

    def policy_shape(self, case: str, scale: Any = None) -> PolicyShape:
        """Unit layout without training a scene: the walk order is a pure
        function of the NGP config the scale implies (hash levels
        coarse->fine, then per-MLP-layer activation/weight pairs)."""
        from repro.core.closed_loop import SceneScale
        from repro.core.env import EnvConfig
        from repro.nerf.hash_encoding import HashEncodingConfig
        from repro.nerf.ngp import NGPConfig, make_quant_units

        scale = scale if scale is not None else SceneScale()
        cfg = NGPConfig(
            hash=HashEncodingConfig(
                n_levels=scale.n_levels, log2_table_size=scale.log2_table,
                base_resolution=4, max_resolution=scale.max_res,
            ),
            hidden_dim=scale.hidden, color_hidden_dim=scale.hidden,
            geo_feat_dim=15, sh_degree=3,
        )
        units = make_quant_units(cfg)
        ecfg = EnvConfig()
        return PolicyShape(
            n_units=len(units), b_min=ecfg.b_min, b_max=ecfg.b_max,
            labels=tuple(u.name for u in units),
        )

    def build_bundle(
        self,
        case: str,
        *,
        scale: Any = None,
        seed: int = 0,
        sharded: Optional[bool] = None,
        hardware: Any = None,
    ) -> WorkloadBundle:
        from repro.core.closed_loop import SceneScale, build_scene_bundle

        return build_scene_bundle(
            case,
            scale if scale is not None else SceneScale(),
            seed=seed,
            sharded=sharded,
            hardware=hardware if hardware is not None
            else self.default_hardware,
        )

    def describe(self) -> dict:
        return {"kind": self.kind}
