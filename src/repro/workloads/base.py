"""The `Workload` protocol: what the HERO closed loop needs from a task.

Nothing in the closed loop — population CEM + DDPG proposals, Pareto
frontier with exact hypervolume, cell-granular checkpoint/resume, the
elastic orchestrator — is NeRF-specific. A workload packages the five
things the loop consumes for one *case* (a NeRF scene name, an LM arch
id) behind one bundle:

  1. policy shape    — bit-vector layout + bounds (`policy_shape`,
                       `env.n_units`, `env.ecfg.b_min/b_max`)
  2. quality proxy   — batched/vmappable ranking signal
                       (`benv.proxy_quality`, `benv.evaluate_population`)
  3. full eval       — exact per-policy quality (`env.evaluate_bits`)
  4. hardware cost   — a registered `HardwareTarget` adapter
                       (`benv.simulate_batch`, `env.original_cost`)
  5. baseline anchor — the all-8-bit point every objective is normalized
                       against (`bundle.baseline_point/normalize`)

The loop drives the bundle duck-typed, through exactly the surface
`hero_population_search` and `HeroSearchRun` already used for NeRF:

  env:  `n_units`, `ecfg.b_min/b_max/lam/latency_target`,
        `observation(i, prev)` (7-dim, `DDPGConfig.obs_dim`),
        `actions_to_bits`, `enforce_latency_target(bits, target=)`,
        `evaluate_bits(bits)`, `original_cost`, `params`
  benv: `env`, `sharded`, `evaluate_population(bits, latency_target=)`
        -> `repro.core.batched_env.PopulationEval`, `simulate_batch`,
        `proxy_quality(params, bits_batch)`, `psnr_org_proxy`

Implementations live next door (`repro.workloads.nerf`,
`repro.workloads.lm`) and are resolved by name through the registry in
`repro.workloads.__init__` (`ClosedLoopConfig.workload`).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Optional, Protocol, Tuple, runtime_checkable

if TYPE_CHECKING:  # repro.core imports this module at package-init time
    from repro.core.pareto import ParetoPoint


@dataclasses.dataclass(frozen=True)
class PolicyShape:
    """Bit-vector layout of one case: how many decisions the episode walk
    makes and the bounds each one is clipped to (Eq. 3)."""

    n_units: int
    b_min: int
    b_max: int
    labels: Tuple[str, ...] = ()  # per-unit names, len == n_units when set


@dataclasses.dataclass
class WorkloadBundle:
    """Everything the loop needs per case, built once and shared across
    budgets: the scalar env (full-fidelity eval, constraint enforcement,
    8-bit baselines) and its batched/sharded population wrapper.

    `scene` is the case name — a NeRF scene or an LM arch id; the frontier
    tags and checkpoint scene_meta key on it. (The field keeps its NeRF
    name: it is the checkpoint schema-v2 wire name.)
    """

    scene: str
    env: Any
    benv: Any
    baseline_latency: float  # all-8-bit cost (env.original_cost)
    baseline_psnr: float  # all-8-bit quality through the proxy
    # All-8-bit model size — the denominator of the joint frontier's size
    # ratio (for NeRF, the PACKED artifact bytes; for LM, the streamed
    # weight bytes of the roofline model).
    baseline_bytes: float

    def baseline_point(self) -> "ParetoPoint":
        from repro.core.pareto import ParetoPoint

        return ParetoPoint(
            latency=self.baseline_latency,
            psnr=self.baseline_psnr,
            model_bytes=self.baseline_bytes,
            bits=tuple([8] * self.env.n_units),
            scene=self.scene,
            reward=0.0,
        )

    def normalize(self, p: "ParetoPoint") -> "ParetoPoint":
        """Raw metrics -> case-normalized objectives (cross-case joint
        frontier): latency/size as ratios vs the 8-bit baseline, quality
        as a delta against the 8-bit proxy quality."""
        return dataclasses.replace(
            p,
            latency=p.latency / self.baseline_latency,
            psnr=p.psnr - self.baseline_psnr,
            model_bytes=p.model_bytes / self.baseline_bytes,
        )


@runtime_checkable
class Workload(Protocol):
    """One task family the closed loop can search over."""

    kind: str  # registry name ("nerf", "lm")
    default_hardware: str  # registered HardwareTarget the family scores on

    def policy_shape(self, case: str, scale: Any = None) -> PolicyShape:
        """Cheap (no training / param init) layout of `case`'s bit vector."""
        ...

    def build_bundle(
        self,
        case: str,
        *,
        scale: Any = None,
        seed: int = 0,
        sharded: Optional[bool] = None,
        hardware: Any = None,
    ) -> WorkloadBundle:
        """Build the case's env + batched env + 8-bit baselines.

        `hardware` is a registered target name or `HardwareTarget`
        instance; None means the workload's `default_hardware`. `scale`
        is the family's env-building knob object (`SceneScale` for NeRF,
        `LMEnvConfig` for LM); None means the family default.
        """
        ...
