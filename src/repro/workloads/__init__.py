"""Workload registry: `ClosedLoopConfig.workload` name -> `Workload`.

Factories import lazily so `repro.core.closed_loop` can depend on this
package (for `WorkloadBundle` and by-name resolution) while the concrete
workloads depend back on `repro.core` without a cycle.
"""
from __future__ import annotations

from typing import Callable, Dict

from repro.workloads.base import PolicyShape, Workload, WorkloadBundle

_WORKLOAD_REGISTRY: Dict[str, tuple] = {}  # name -> (factory, description)


def register_workload(name: str, factory: Callable[..., Workload],
                      description: str = "") -> None:
    """Register a workload factory under `name`. Factories take keyword
    overrides and return a fresh `Workload`."""
    _WORKLOAD_REGISTRY[name] = (factory, description)


def get_workload(name: str, **overrides) -> Workload:
    """Instantiate a registered workload by name."""
    if name not in _WORKLOAD_REGISTRY:
        known = ", ".join(sorted(_WORKLOAD_REGISTRY))
        raise KeyError(
            f"unknown workload {name!r} (registered: {known})"
        )
    factory, _ = _WORKLOAD_REGISTRY[name]
    return factory(**overrides)


def list_workloads() -> Dict[str, str]:
    """name -> one-line description of every registered workload."""
    return {k: d for k, (_, d) in sorted(_WORKLOAD_REGISTRY.items())}


def _nerf_factory(**kw) -> Workload:
    from repro.workloads.nerf import NerfSceneWorkload

    return NerfSceneWorkload(**kw)


def _lm_factory(**kw) -> Workload:
    from repro.workloads.lm import LMWorkload

    return LMWorkload(**kw)


register_workload(
    "nerf", _nerf_factory,
    "NeRF scene quantization (hash levels + MLP W/A bits, NeuRex-family "
    "targets) — the paper's original task",
)
register_workload(
    "lm", _lm_factory,
    "LM quantization (embed-band + per-layer W/A bits, real forward-pass "
    "loss deltas, roofline-lm decode cost)",
)

__all__ = [
    "PolicyShape",
    "Workload",
    "WorkloadBundle",
    "register_workload",
    "get_workload",
    "list_workloads",
]
