"""Batched serving driver: prefill + decode loop with a request queue.

Serving shape of the system: requests arrive with prompts, get batched,
prefilled into a shared KV cache, then decoded step-by-step (continuous
batching is approximated by slot recycling: a finished sequence's slot is
refilled from the queue at the next prefill boundary).

On CPU this runs the smoke configs; the production path is the same code
under the pod mesh, where the cache seq axis is sharded over `model`
(flash-decoding) per repro/distributed/sharding.cache_pspecs.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --batch 4 --prompt-len 16 --gen 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import lm


def greedy(logits):
    return jnp.argmax(logits[:, -1, :], axis=-1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    model = spec.smoke if args.smoke else spec.model
    mesh = make_host_mesh()
    max_seq = args.prompt_len + args.gen

    params = lm.init_params(model, jax.random.PRNGKey(0))
    prefill_fn = jax.jit(make_prefill_step(model, max_seq))
    decode_fn = jax.jit(make_decode_step(model), donate_argnums=(1,))

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab_size=model.vocab_size, seq_len=args.prompt_len,
        global_batch=args.batch,
    ))

    def make_request_batch():
        batch = {"tokens": jnp.asarray(pipe.batch())}
        if model.embed_frontend == "prefix_patches":
            p = model.n_prefix_patches
            batch["patches"] = jnp.zeros(
                (args.batch, p, model.d_model), model.param_dtype
            )
        elif model.embed_frontend == "stub_frames":
            batch["frames"] = jnp.zeros(
                (args.batch, model.max_source_len, model.d_model),
                model.param_dtype,
            )
        return batch

    served = 0
    t0 = time.time()
    total_tokens = 0
    with mesh:
        while served < args.requests:
            batch = make_request_batch()
            logits, cache = prefill_fn(params, batch)
            prompt_extra = (
                model.n_prefix_patches
                if model.embed_frontend == "prefix_patches" else 0
            )
            pos = args.prompt_len + prompt_extra
            tok = greedy(logits)[:, None]
            outs = [np.asarray(tok)]
            for i in range(args.gen - 1):
                logits, cache = decode_fn(
                    params, cache, tok, jnp.int32(pos + i)
                )
                tok = greedy(logits)[:, None]
                outs.append(np.asarray(tok))
            gen = np.concatenate(outs, axis=1)
            assert gen.shape == (args.batch, args.gen)
            assert np.all(gen >= 0) and np.all(gen < model.vocab_size)
            served += args.batch
            total_tokens += gen.size
            print(f"served {served}/{args.requests} requests; "
                  f"sample: {gen[0, :8].tolist()}")
    dt = time.time() - t0
    print(f"done: {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s on {jax.default_backend()})")


if __name__ == "__main__":
    main()
