"""Step builders: train_step (grad-accumulation + AdamW) and serve steps.

These are the functions the dry-run lowers and the launcher runs. All are
pure (params, state, batch) -> (params, state, metrics) so pjit shards them
from the in/out shardings alone.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    ShardingConfig,
    batch_axes,
    cache_pspecs,
    data_pspecs,
    param_pspecs,
)
from repro.models import lm
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, clip_by_global_norm
from repro.optim.state_codec import Quantized


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    moment_dtype: str = "f32",
    grad_clip: float = 1.0,
    accum_dtype=jnp.float32,
    grad_pspecs=None,
) -> Callable:
    """batch leaves are (A, microbatch, ...): an accumulation scan runs the
    A microbatches, then one AdamW update applies the mean gradient.

    grad_pspecs (PartitionSpec tree matching params) constrains the f32
    gradient accumulator to the PARAM sharding. Without it GSPMD keeps the
    accumulator replicated, which forces a full-gradient all-reduce every
    microbatch — the sharded accumulator turns that into a per-micro
    reduce-scatter of the bf16 contribution (ZeRO-2), ~32x less inter-chip
    traffic at llama3-405b scale (measured in EXPERIMENTS.md §Perf)."""

    def constrain(tree):
        if grad_pspecs is None:
            return tree
        return jax.tree_util.tree_map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s),
            tree, grad_pspecs, is_leaf=lambda x: isinstance(x, P),
        )

    def train_step(params, opt_state, batch):
        def micro(acc, mb):
            (loss, _metrics), grads = jax.value_and_grad(
                lm.loss_fn, has_aux=True
            )(params, mb, cfg)
            grads = constrain(grads)  # reduce-scatter HERE, in bf16
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), acc, grads
            )
            return constrain(acc), loss

        zeros = constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, accum_dtype), params
        ))
        grads, losses = jax.lax.scan(micro, zeros, batch)
        A = losses.shape[0]
        grads = jax.tree_util.tree_map(lambda g: g / A, grads)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = adamw_update(
            grads, opt_state, params, opt_cfg, moment_dtype=moment_dtype
        )
        metrics = {"loss": jnp.mean(losses), "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int) -> Callable:
    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, max_seq)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, tokens, pos):
        return lm.decode_step(params, cache, tokens, pos, cfg)

    return decode_step


# ---------------------------------------------------------------------------
# Sharding trees for full step signatures
# ---------------------------------------------------------------------------
def opt_state_pspecs(params_spec_tree, moment_dtype: str = "f32"):
    """AdamWState sharding mirroring the param shardings (ZeRO: the moments
    are sharded exactly like the FSDP+TP params). int8 moments: codes take
    the param spec, row scales drop the last axis."""

    def moment(pspec):
        if moment_dtype != "int8":
            return pspec
        entries = tuple(pspec)
        scale = P(*entries[:-1], None) if entries else P()
        return Quantized(codes=pspec, scale=scale)

    is_p = lambda x: isinstance(x, P)
    from repro.optim.adamw import AdamWState

    return AdamWState(
        step=P(),
        mu=jax.tree_util.tree_map(moment, params_spec_tree, is_leaf=is_p),
        nu=jax.tree_util.tree_map(moment, params_spec_tree, is_leaf=is_p),
    )


def accum_batch_pspecs(batch, mesh: Mesh, scfg: ShardingConfig):
    """(A, microbatch, ...) leaves: batch dim 1 over the DP axes."""
    bax = batch_axes(mesh, scfg)
    b = bax if len(bax) > 1 else (bax[0] if bax else None)

    def leaf_spec(leaf):
        if leaf.ndim < 2:
            return P()
        return P(*((None, b) + (None,) * (leaf.ndim - 2)))

    return jax.tree_util.tree_map(leaf_spec, batch)


def train_shardings(
    params_sds,
    opt_sds,
    batch_sds,
    mesh: Mesh,
    scfg: ShardingConfig,
    moment_dtype: str = "f32",
):
    """(in_shardings, out_shardings) for train_step."""
    pspec = param_pspecs(params_sds, scfg, mesh)
    ospec = opt_state_pspecs(pspec, moment_dtype)
    bspec = accum_batch_pspecs(batch_sds, mesh, scfg)
    n = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    mspec = {"loss": NamedSharding(mesh, P()), "grad_norm": NamedSharding(mesh, P())}
    return (n(pspec), n(ospec), n(bspec)), (n(pspec), n(ospec), mspec)
