"""End-to-end distributed LM training driver.

Wires together every substrate layer: config registry -> data pipeline ->
sharded init -> jit'd train_step (accumulation + AdamW + ZeRO) ->
fault-tolerant checkpointing (atomic, async, exactly-resumable data state)
-> straggler/failure handling hooks (repro/distributed/fault_tolerance).

On this CPU container it runs the reduced smoke configs end-to-end; on a
pod it runs the full configs unchanged (the mesh is the only difference).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt --resume
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import SHAPES, get_arch
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.distributed.sharding import ShardingConfig, param_pspecs
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step, opt_state_pspecs, train_shardings
from repro.models import lm
from repro.optim import AdamWConfig, adamw_init


def build_batch_fn(model, pipe, accum, microbatch):
    """Host-side batch assembly: (A, mb, S) token stacks + frontend stubs."""

    def next_batch():
        toks = np.stack([pipe.batch() for _ in range(accum)])  # (A, mb, S)
        batch = {"tokens": jnp.asarray(toks)}
        if model.embed_frontend == "prefix_patches":
            p = model.n_prefix_patches
            batch["patches"] = jnp.zeros(
                (accum, microbatch, p, model.d_model), model.param_dtype
            )
            batch["tokens"] = batch["tokens"][..., : toks.shape[-1] - p]
        elif model.embed_frontend == "stub_frames":
            batch["frames"] = jnp.zeros(
                (accum, microbatch, model.max_source_len, model.d_model),
                model.param_dtype,
            )
        return batch

    return next_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 pod mesh (needs 256 devices)")
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    model = spec.smoke if args.smoke else spec.model
    mesh = (
        make_production_mesh() if args.production_mesh else make_host_mesh()
    )
    scfg = ShardingConfig()
    assert args.global_batch % args.accum == 0
    microbatch = args.global_batch // args.accum

    pipe_cfg = TokenPipelineConfig(
        vocab_size=model.vocab_size,
        seq_len=args.seq_len,
        global_batch=microbatch,
        seed=0,
    )
    pipe = TokenPipeline(pipe_cfg)

    # --- init (sharded from birth via jit out_shardings) -----------------
    params_sds = jax.eval_shape(lambda k: lm.init_params(model, k),
                                jax.random.PRNGKey(0))
    pspec = param_pspecs(params_sds, scfg, mesh)
    nshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspec,
        is_leaf=lambda x: isinstance(x, P),
    )
    with mesh:
        params = jax.jit(
            lambda k: lm.init_params(model, k), out_shardings=nshard
        )(jax.random.PRNGKey(0))
        opt_state = jax.jit(
            lambda p: adamw_init(p, moment_dtype=spec.moment_dtype),
        )(params)

    opt_cfg = AdamWConfig(lr=args.lr, weight_decay=0.1)
    step_fn = make_train_step(
        model, opt_cfg, moment_dtype=spec.moment_dtype, grad_pspecs=pspec
    )
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    start = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3, async_write=True)
        if args.resume and latest_step(args.ckpt_dir) is not None:
            state_sds = jax.eval_shape(lambda: (params, opt_state))
            ospec = opt_state_pspecs(pspec, spec.moment_dtype)
            onshard = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), ospec,
                is_leaf=lambda x: isinstance(x, P),
            )
            # Elastic restore: device_put with the CURRENT mesh's shardings
            # re-shards host arrays regardless of the saving mesh shape.
            (params, opt_state), extra = restore_checkpoint(
                args.ckpt_dir, like=state_sds, shardings=(nshard, onshard)
            )
            pipe = TokenPipeline.from_state(pipe_cfg, extra)
            start = int(extra["train_step"])
            print(f"resumed at step {start} (data step {pipe.step})")

    next_batch = build_batch_fn(model, pipe, args.accum, microbatch)

    with mesh:
        for step in range(start, args.steps):
            t0 = time.time()
            batch = next_batch()
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"dt {time.time()-t0:.2f}s")
            assert np.isfinite(loss), "training diverged"
            if mgr and (step + 1) % args.ckpt_every == 0:
                extra = {**pipe.state(), "train_step": step + 1}
                mgr.save(step + 1, (params, opt_state), extra)
    if mgr:
        mgr.close()
    return params


if __name__ == "__main__":
    main()
