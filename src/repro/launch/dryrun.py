import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Roofline counting needs the post-SPMD, pre-backend-legalization HLO: the
# CPU backend's float normalization rewrites every bf16 op to f32, which
# would inflate all byte/collective counts 2x vs the TPU target (see
# DESIGN.md "CPU dry-run caveats"). The dump keeps original dtypes.
_DUMP_DIR = os.environ.get("REPRO_XLA_DUMP", "/tmp/repro_xla_dump")
os.environ["XLA_FLAGS"] += (
    f" --xla_dump_to={_DUMP_DIR} --xla_dump_hlo_pass_re=spmd-partitioning"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  - the sharding config is coherent (GSPMD partitions without error),
  - the per-device memory fits (memory_analysis),
  - and it yields the roofline inputs (cost_analysis FLOPs/bytes +
    collective bytes parsed from the compiled HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch xlstm-350m \
      --shape train_4k --mesh both --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    SHAPES,
    ARCH_IDS,
    get_arch,
    train_input_specs,
    prefill_input_specs,
    decode_input_specs,
)
from repro.configs.base import ArchSpec, ShapeSpec
from repro.distributed.hlo_analysis import ChipSpec, RooflineTerms
from repro.distributed.hlo_counters import analyze as hlo_analyze
from repro.distributed.sharding import (
    ShardingConfig,
    batch_axes,
    cache_pspecs,
    param_pspecs,
    prune_pspecs,
    validate_divisibility,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
    opt_state_pspecs,
    train_shardings,
)
from repro.models import lm
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, adamw_init


# ---------------------------------------------------------------------------
# Post-SPMD dump plumbing
# ---------------------------------------------------------------------------
def _clear_dump():
    d = Path(_DUMP_DIR)
    if d.exists():
        for f in d.iterdir():
            try:
                f.unlink()
            except OSError:
                pass


def _read_spmd_dump(expect_name: str = "") -> str:
    """Newest post-SPMD dump whose module name matches the lowered step
    (guards against stale files from other compilations)."""
    d = Path(_DUMP_DIR)
    cands = sorted(
        d.glob(f"*{expect_name}*after_spmd-partitioning*"),
        key=lambda p: p.stat().st_mtime,
    )
    if not cands:
        raise FileNotFoundError(
            f"no after_spmd-partitioning dump for {expect_name!r} in "
            f"{_DUMP_DIR}; XLA_FLAGS dump flags did not take effect"
        )
    return cands[-1].read_text()


def _model_flops(spec: ArchSpec, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active params."""
    n = active_params(spec.model)
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def active_params(cfg: ModelConfig) -> float:
    """Params touched per token: MoE counts top_k experts, not all."""
    total = cfg.n_params()
    if cfg.moe is None:
        return float(total)
    m = cfg.moe
    dffe = m.d_ff_expert or cfg.d_ff
    glu = cfg.ffn_type in ("swiglu", "geglu")
    per_expert = cfg.d_model * dffe * (3 if glu else 2)
    n_moe_layers = sum(
        1
        for l in range(cfg.n_layers)
        if l % m.every_n_layers == m.every_n_layers - 1
    )
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return float(total - inactive)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------
def _act_pspec(multi_pod: bool):
    dp = ("pod", "data") if multi_pod else "data"
    return (dp, "model", None)  # Megatron-SP: residuals sharded over seq


def build_cell(spec: ArchSpec, shape: ShapeSpec, multi_pod: bool):
    """Returns (jitted fn, example args as ShapeDtypeStructs, mesh, scfg)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_total = int(np.prod([s for a, s in zip(mesh.axis_names, mesh.devices.shape)
                            if a in ("pod", "data")]))
    scfg = ShardingConfig(tp_axis=None) if spec.no_tp else ShardingConfig()
    model = spec.model

    if shape.kind == "train":
        ap = _act_pspec(multi_pod)
        if spec.no_tp:
            ap = (ap[0], None, None)  # no seq/TP sharding for small models
        model = dataclasses.replace(model, act_pspec=ap)
        if model.moe is not None:
            # per-rank capacity: one dispatch group per DP shard
            model = dataclasses.replace(
                model, moe=dataclasses.replace(
                    model.moe, dispatch_groups=dp_total)
            )
        mb = max(spec.microbatch.get(shape.name, 32), dp_total)
        accum = max(shape.global_batch // mb, 1)
        micro_sds = train_input_specs(model, shape, mb)
        batch_sds = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((accum,) + s.shape, s.dtype), micro_sds
        )
        params_sds = lm.param_specs(model)
        opt_sds = jax.eval_shape(
            functools.partial(adamw_init, moment_dtype=spec.moment_dtype),
            params_sds,
        )
        gspecs = param_pspecs(params_sds, scfg, mesh)
        step = make_train_step(model, AdamWConfig(lr=1e-4, weight_decay=0.1),
                               moment_dtype=spec.moment_dtype,
                               grad_pspecs=gspecs)
        in_sh, out_sh = train_shardings(
            params_sds, opt_sds, batch_sds, mesh, scfg, spec.moment_dtype
        )
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds)
        return fn, args, mesh, scfg

    named = lambda t: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    bax = batch_axes(mesh, scfg)
    batch_ok = shape.global_batch % int(
        np.prod([mesh.shape[a] for a in bax])
    ) == 0
    b = (bax if len(bax) > 1 else bax[0]) if (bax and batch_ok) else None
    params_sds = lm.param_specs(model)
    pspec = param_pspecs(params_sds, scfg, mesh)

    vocab_ax = "model" if model.vocab_size % mesh.shape["model"] == 0 else None
    if shape.kind == "prefill":
        batch_sds = prefill_input_specs(model, shape)
        bspec = jax.tree_util.tree_map(
            lambda s: P(*((b,) + (None,) * (s.ndim - 1))), batch_sds
        )
        cache_sds = lm.cache_specs(model, shape.global_batch, shape.seq_len)
        cspec = cache_pspecs(cache_sds, mesh, scfg)
        if not batch_ok:
            cspec = _drop_batch_axis(cspec)
        cspec = prune_pspecs(cspec, cache_sds, mesh)
        logits_spec = P(b, None, vocab_ax)
        step = make_prefill_step(model, shape.seq_len)
        fn = jax.jit(
            step,
            in_shardings=(named(pspec), named(bspec)),
            out_shardings=(
                NamedSharding(mesh, logits_spec), named(cspec)),
        )
        return fn, (params_sds, batch_sds), mesh, scfg

    # decode
    io_sds = decode_input_specs(model, shape)
    cache_sds = lm.cache_specs(model, shape.global_batch, shape.seq_len)
    cspec = cache_pspecs(cache_sds, mesh, scfg)
    if not batch_ok:
        cspec = _drop_batch_axis(cspec)
    cspec = prune_pspecs(cspec, cache_sds, mesh)
    tok_spec = P(b, None)
    logits_spec = P(b, None, vocab_ax)
    step = make_decode_step(model)
    fn = jax.jit(
        step,
        in_shardings=(
            named(pspec), named(cspec),
            NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()),
        ),
        out_shardings=(NamedSharding(mesh, logits_spec), named(cspec)),
        donate_argnums=(1,),  # cache updated in place
    )
    args = (params_sds, cache_sds, io_sds["tokens"], io_sds["pos"])
    return fn, args, mesh, scfg


def _drop_batch_axis(spec_tree):
    """Replace the batch axis (dim 1 after the period-stack dim) with None
    when the global batch does not divide the DP axes (e.g. long_500k B=1)."""

    def fix(s):
        entries = list(s)
        if len(entries) >= 2:
            entries[1] = None
        return P(*entries)

    return jax.tree_util.tree_map(
        fix, spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


def run_cell(spec: ArchSpec, shape: ShapeSpec, multi_pod: bool,
             out_dir: Path, chip: ChipSpec = ChipSpec()) -> dict:
    cell = f"{spec.arch_id} x {shape.name} x {'2pod' if multi_pod else '1pod'}"
    t0 = time.time()
    fn, args, mesh, scfg = build_cell(spec, shape, multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    _clear_dump()
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = int(v)
    except Exception as e:  # pragma: no cover - backend dependent
        mem["error"] = str(e)

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    cost = {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "utilization operand 0",
             "bytes accessed output")}

    # Loop-aware counters over the post-SPMD dump: cost_analysis() visits
    # while bodies ONCE (undercounts scanned programs by orders of
    # magnitude) and the CPU backend f32-normalizes bf16 (2x inflation);
    # the after_spmd-partitioning dump has per-device shapes, original
    # dtypes, and statically known trip counts.
    step_name = {"train": "train_step", "prefill": "prefill_step",
                 "decode": "decode_step"}[shape.kind]
    hlo = _read_spmd_dump(step_name)
    counters = hlo_analyze(hlo, n_devices=n_dev, fused_bytes=False)
    terms = RooflineTerms(
        compute_s=counters.flops / chip.peak_flops_bf16,
        memory_s=counters.bytes / chip.hbm_bw,
        collective_s=counters.link_bytes / chip.ici_bw,
        hlo_flops=counters.flops * n_dev,
        hlo_bytes=counters.bytes * n_dev,
        collective_bytes=counters.link_bytes,
        model_flops=_model_flops(spec, shape),
    )
    coll_counts = counters.coll_counts
    coll_bytes = counters.coll_bytes

    # Per-device weight bytes (analytic) for the memory report.
    param_bytes = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(args[0])
    )

    result = {
        "cell": cell,
        "arch": spec.arch_id,
        "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "cost_analysis_raw_body_once": cost,
        "memory_analysis": mem,
        "param_bytes_global": param_bytes,
        "param_bytes_per_device": param_bytes / n_dev,
        "dot_flops_per_device": counters.dot_flops,
        "collectives": {
            "counts": coll_counts,
            "bytes_by_kind": coll_bytes,
            "per_device_link_bytes": counters.link_bytes,
        },
        "roofline": terms.as_dict(),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{spec.arch_id}__{shape.name}__{result['mesh'].replace('x','_')}.json"
    (out_dir / fname).write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    n_ok = n_skip = n_fail = 0
    for aid in archs:
        spec = get_arch(aid)
        for sname in shapes:
            shape = SHAPES[sname]
            if not spec.runs(sname):
                print(f"SKIP {aid} x {sname}: {spec.skips[sname]}")
                n_skip += 1
                continue
            for mp in meshes:
                tag = "2pod" if mp else "1pod"
                try:
                    r = run_cell(spec, shape, mp, out_dir)
                    rf = r["roofline"]
                    print(
                        f"OK   {aid} x {sname} x {tag}: "
                        f"compile={r['compile_s']}s "
                        f"compute={rf['compute_s']:.3e}s "
                        f"memory={rf['memory_s']:.3e}s "
                        f"coll={rf['collective_s']:.3e}s "
                        f"dom={rf['dominant']}"
                    )
                    n_ok += 1
                except Exception:
                    print(f"FAIL {aid} x {sname} x {tag}:")
                    traceback.print_exc()
                    n_fail += 1
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (recorded), {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
