"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax init).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is pure
data parallelism — the only traffic that crosses the inter-pod DCN/ICI
boundary is the once-per-step gradient all-reduce.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across versions: AxisType landed in jax 0.5."""
    if hasattr(jax.sharding, "AxisType"):
        types = (jax.sharding.AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=types)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (smoke tests / examples): 1xN."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
