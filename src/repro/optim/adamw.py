"""AdamW in pure JAX, pytree-structured state.

The moment pytrees mirror the param pytree exactly, so any sharding applied to
params can be applied verbatim to optimizer state (this is what lets the
launcher implement ZeRO-1 by just re-sharding the state pytree over the data
axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # Parameters whose path contains one of these substrings get no decay
    # (biases, norms, embeddings by convention).
    no_decay_substrings: tuple = ("bias", "norm", "scale_param")


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: Any  # first moment, same pytree as params
    nu: Any  # second moment, same pytree as params


def adamw_init(params: Any, moment_dtype: str = "param") -> AdamWState:
    """moment_dtype: 'param' (match param dtype), 'f32', 'bf16', or 'int8'
    (blockwise 8-bit Adam, see optim/state_codec.py)."""
    if moment_dtype == "param":
        zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)
    from repro.optim.state_codec import moment_codecs

    mu_c, nu_c = moment_codecs(moment_dtype)
    mu = jax.tree_util.tree_map(mu_c.init, params)
    nu = jax.tree_util.tree_map(nu_c.init, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    config: AdamWConfig,
    lr_schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    moment_dtype: str = "param",
):
    """One AdamW step. Returns (new_params, new_state). moment_dtype must
    match what adamw_init was called with ('int8' round-trips the moments
    through the blockwise codec around the update)."""
    step = state.step + 1
    lr = config.lr if lr_schedule is None else lr_schedule(step) * config.lr

    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    mu_in, nu_in = state.mu, state.nu
    if moment_dtype != "param":
        from repro.optim.state_codec import moment_codecs, tree_decode

        mu_c, nu_c = moment_codecs(moment_dtype)
        mu_in = tree_decode(mu_c, mu_in)
        nu_in = tree_decode(nu_c, nu_in)

    new_mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype), mu_in, grads
    )
    new_nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(v.dtype)),
        nu_in, grads,
    )

    def _upd(path, p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        update = mhat / (jnp.sqrt(vhat) + config.eps)
        if config.weight_decay > 0.0:
            ps = _path_str(path)
            decayed = not any(s in ps for s in config.no_decay_substrings)
            if decayed:
                update = update + config.weight_decay * p
        return (p - lr * update).astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(_upd, params, new_mu, new_nu)
    if moment_dtype != "param":
        from repro.optim.state_codec import tree_encode

        new_mu = tree_encode(mu_c, new_mu, params)
        new_nu = tree_encode(nu_c, new_nu, params)
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
