"""Optimizer substrate (no external deps beyond jax).

Provides AdamW with decoupled weight decay, global-norm gradient clipping,
and standard LR schedules. State is a pytree mirroring the params pytree, so
it shards the same way params do (ZeRO-1 = shard both over the data axis).
"""
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, AdamWConfig
from repro.optim.schedules import (
    constant_schedule,
    cosine_schedule,
    linear_warmup_cosine,
    exponential_decay,
)
from repro.optim.clipping import global_norm, clip_by_global_norm

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "AdamWConfig",
    "constant_schedule",
    "cosine_schedule",
    "linear_warmup_cosine",
    "exponential_decay",
    "global_norm",
    "clip_by_global_norm",
]
