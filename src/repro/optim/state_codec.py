"""Optimizer-state codecs: f32 / bf16 / blockwise-int8 Adam moments.

int8 moments ("8-bit Adam") are what let the ~0.5T-param assigned archs
(arctic-480b, llama3-405b, nemotron-4-340b) train on 16 GB/chip v5e HBM:
p(bf16) + g(f32 accum) + m,v(int8) fits where f32 moments do not — the
quantization theme of the paper applied to the optimizer (DESIGN.md §5).

Encoding: symmetric absmax over the last axis (row-wise scales). The second
moment is encoded on a sqrt scale to compress its dynamic range. Codes keep
the parameter's shape (so parameter sharding rules apply verbatim); scales
drop the last axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    codes: jnp.ndarray  # int8, same shape as the logical tensor
    scale: jnp.ndarray  # f32, shape[:-1] + (1,)


def _encode(x: jnp.ndarray) -> Quantized:
    x = x.astype(jnp.float32)
    a = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    a = jnp.maximum(a, 1e-12)
    return Quantized(jnp.round(x / a).astype(jnp.int8), a)


def _decode(q: Quantized) -> jnp.ndarray:
    return q.codes.astype(jnp.float32) * q.scale


class MomentCodec:
    """encode/decode one moment leaf. kind in {f32, bf16, int8, param}."""

    def __init__(self, kind: str = "param", sqrt_domain: bool = False):
        self.kind = kind
        self.sqrt_domain = sqrt_domain

    def encode(self, x: jnp.ndarray, like: jnp.ndarray):
        if self.kind == "param":
            return x.astype(like.dtype)
        if self.kind in ("f32", "float32"):
            return x.astype(jnp.float32)
        if self.kind in ("bf16", "bfloat16"):
            return x.astype(jnp.bfloat16)
        if self.kind == "int8":
            y = jnp.sqrt(jnp.maximum(x, 0.0)) if self.sqrt_domain else x
            return _encode(y)
        raise ValueError(self.kind)

    def decode(self, s) -> jnp.ndarray:
        if isinstance(s, Quantized):
            y = _decode(s)
            return jnp.square(y) if self.sqrt_domain else y
        return s.astype(jnp.float32)

    def init(self, p: jnp.ndarray):
        return self.encode(jnp.zeros(p.shape, jnp.float32), p)


def moment_codecs(moment_dtype: str):
    """(mu codec, nu codec). nu uses the sqrt domain under int8."""
    return (
        MomentCodec(moment_dtype, sqrt_domain=False),
        MomentCodec(moment_dtype, sqrt_domain=moment_dtype == "int8"),
    )


def is_quantized(x) -> bool:
    return isinstance(x, Quantized)


def tree_encode(codec: MomentCodec, tree: Any, like: Any):
    return jax.tree_util.tree_map(codec.encode, tree, like)


def tree_decode(codec: MomentCodec, tree: Any):
    return jax.tree_util.tree_map(codec.decode, tree, is_leaf=is_quantized)
