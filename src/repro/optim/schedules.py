"""LR schedules as step -> multiplier callables (multiplied by base lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule():
    def sched(step):
        return jnp.ones_like(jnp.asarray(step, jnp.float32))

    return sched


def cosine_schedule(total_steps: int, final_frac: float = 0.0):
    def sched(step):
        t = jnp.clip(jnp.asarray(step, jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return final_frac + (1.0 - final_frac) * cos

    return sched


def linear_warmup_cosine(warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched


def exponential_decay(decay_steps: int, decay_rate: float = 0.5):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        return decay_rate ** (step / max(decay_steps, 1))

    return sched
