"""Gradient clipping utilities."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    return jnp.sqrt(sq)


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    """Returns (clipped_tree, pre_clip_norm)."""
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    clipped = jax.tree_util.tree_map(lambda l: (l * scale).astype(l.dtype), tree)
    return clipped, norm
